"""Training loop: jitted train_step factory + host-side driver.

``make_train_step`` is the single source of truth for the training step —
the same function is (a) executed by the training example on CPU and
(b) lowered against ShapeDtypeStructs on the production mesh by the dry-run
(deliverable (e)). Sharding flows in through logical-axis rules installed by
the caller (see ``repro.sharding``), not through this module.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model, RuntimeFlags
from .optimizer import OptimizerConfig, AdamWState, adamw_update, init_adamw


@dataclass
class TrainState:
    params: dict
    opt: AdamWState


def make_train_step(model: Model, opt_cfg: OptimizerConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: dict):
        def loss_fn(params):
            loss, parts = model.loss(params, batch)
            return loss, parts

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params)
        new_params, new_opt, om = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **parts, **om}
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=init_adamw(params))


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt"], meta_fields=[])


@dataclass
class TrainLog:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    wall: list = field(default_factory=list)


def train_loop(model: Model, opt_cfg: OptimizerConfig, data_iter,
               num_steps: int, *, key=None, log_every: int = 10,
               checkpoint_path: Optional[str] = None,
               checkpoint_every: int = 0,
               state: Optional[TrainState] = None,
               verbose: bool = True) -> tuple:
    """Host driver: returns (final_state, TrainLog)."""
    from . import checkpoint as ckpt

    key = key if key is not None else jax.random.key(0)
    if state is None:
        state = init_state(model, key)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    log = TrainLog()
    t0 = time.perf_counter()
    for step, batch in enumerate(data_iter):
        if step >= num_steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, jb)
        if step % log_every == 0 or step == num_steps - 1:
            loss = float(metrics["loss"])
            log.steps.append(step)
            log.losses.append(loss)
            log.wall.append(time.perf_counter() - t0)
            if verbose:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
        if (checkpoint_path and checkpoint_every
                and step and step % checkpoint_every == 0):
            ckpt.save(checkpoint_path, state.params, step=step)
    if checkpoint_path:
        ckpt.save(checkpoint_path, state.params, step=num_steps)
    return state, log
