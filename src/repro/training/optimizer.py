"""AdamW + cosine LR schedule + global-norm gradient clipping.

Hand-rolled (no optax in this environment) as pure pytree transforms so the
optimizer state shards exactly like the parameters (FSDP over ``data`` in
TRAIN_RULES — this is what makes grok-1-314b's optimizer state fit in the
dry-run's memory analysis).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array      # int32 scalar
    mu: dict             # first moment  (pytree like params)
    nu: dict             # second moment (pytree like params)


def cosine_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio * cfg.lr + (1 - cfg.min_lr_ratio) * cfg.lr \
        * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


_NO_DECAY = ("scale", "bias", "A_log", "D", "dt_bias", "a_param")


def _decay_mask(path) -> bool:
    keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    return not any(k in _NO_DECAY for k in keys)


def adamw_update(cfg: OptimizerConfig, params, grads, state: AdamWState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat[0]]
    p_leaves = [l for _, l in flat[0]]
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state.mu)
    v_leaves = jax.tree.leaves(state.nu)
    out = [upd(pa, p, g, m, v) for pa, p, g, m, v
           in zip(paths, p_leaves, g_leaves, m_leaves, v_leaves)]
    treedef = flat[1]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step, new_mu, new_nu), {
        "lr": lr, "grad_norm": gnorm}
