"""Minimal, dependency-free checkpointing.

Pytrees are flattened with jax.tree_util key-paths into a single ``.npz``
(atomic rename on save). Works for params, optimizer state, and data-pipeline
RNG state. Restores verify structure + shapes so a config change can't load
an incompatible checkpoint silently.
"""
from __future__ import annotations

import os
import tempfile
from typing import Optional

import numpy as np

import jax


_NATIVE = set("?bhilqBHILQefdgFD")


def _to_numpy(leaf) -> np.ndarray:
    arr = np.asarray(leaf)
    if arr.dtype.char not in _NATIVE:      # ml_dtypes (bf16, fp8, ...)
        arr = arr.astype(np.float32)       # lossless widening for bf16
    return arr


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = _to_numpy(leaf)
    return out, treedef


def save(path: str, tree, step: Optional[int] = None):
    arrays, _ = _flatten(tree)
    if step is not None:
        arrays["__step__"] = np.asarray(step)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, leaf in flat:
            key = jax.tree_util.keystr(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != model {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        step = int(data["__step__"]) if "__step__" in data else None
    return jax.tree_util.tree_unflatten(treedef, leaves), step
