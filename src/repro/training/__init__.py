"""Training substrate: AdamW, train loop, checkpointing."""
from .optimizer import (OptimizerConfig, AdamWState, adamw_update,
                        init_adamw, cosine_lr, clip_by_global_norm,
                        global_norm)
from .trainer import TrainState, make_train_step, init_state, train_loop
from . import checkpoint

__all__ = [
    "OptimizerConfig", "AdamWState", "adamw_update", "init_adamw",
    "cosine_lr", "clip_by_global_norm", "global_norm",
    "TrainState", "make_train_step", "init_state", "train_loop", "checkpoint",
]
