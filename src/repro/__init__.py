"""LazyBatching reproduction: SLA-aware node-level batching on JAX/Pallas."""
