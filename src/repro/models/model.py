"""Architecture-generic decoder model.

A single ``Model`` class consumes a ``ModelConfig`` and provides:

  * ``init(key)``            — parameter pytree (works under ``jax.eval_shape``
                               for the dry-run: no device allocation),
  * ``loss(params, batch)``  — training loss (+ MoE aux),
  * ``prefill(params, tokens[, prefix])`` — full-context forward, returns
                               (last-token logits, decode cache),
  * ``decode_step(params, cache, token, pos)`` — ONE token with ragged
                               per-row positions (lazily merged batches),
  * ``init_cache(batch, max_len)``,
  * per-layer block application (``num_blocks`` / ``apply_block_*``) for the
    LazyBatching node-level engine.

Homogeneous layer stacks are ``lax.scan``-ned over stacked parameters
(compact HLO — one while body regardless of depth). ``RuntimeFlags.use_scan
= False`` unrolls the python loop instead; the roofline probe lowers 1- and
2-layer unrolled variants to recover exact per-layer costs (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import ssm as SSM


@dataclass(frozen=True)
class RuntimeFlags:
    dtype: object = jnp.bfloat16
    use_scan: bool = True
    scan_unroll: int = 1
    remat: bool = False
    attn_chunk: int = 2048
    moe_group_rows: int = 1
    # sliding-window variant for long-context decode on attention archs
    window: Optional[int] = None
    # §Perf beyond-paper optimizations (default off = paper-faithful baseline)
    grouped_decode: bool = False     # GQA decode without repeat_kv
    mla_absorbed: bool = False       # MLA prefill in the latent space
    kv_quant: bool = False           # int8 KV cache (GQA decode)
    pallas_decode: bool = False      # ragged-attention Pallas kernel
    # remat policy: "full" recomputes everything; "dots" saves matmul
    # outputs (jax.checkpoint_policies.checkpoint_dots) — trades saved-
    # activation memory for ~25% less recompute FLOPs
    remat_policy: str = "full"


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _index(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _gather_rows(tree, slots):
    """Select per-batch rows out of a slot arena pytree (no-op w/o slots).

    Slot indices are clamped in-bounds: batch-bucketed dispatch pads rows
    with the out-of-bounds slot ``n_slots`` so their scatters drop — the
    clamped gather just reads *some* live row, whose garbage output is
    masked/discarded downstream.
    """
    if slots is None:
        return tree
    return jax.tree.map(
        lambda l: l[jnp.minimum(slots, l.shape[0] - 1)], tree)


def _scatter_rows(arena, rows, slots):
    """Write updated batch rows back into their arena slots. Out-of-bounds
    slots (batch-bucket padding rows) are dropped, not clamped — a padded
    row must never corrupt a live slot."""
    if slots is None:
        return rows
    return jax.tree.map(
        lambda a, r: a.at[slots].set(r.astype(a.dtype), mode="drop"),
        arena, rows)


class Model:
    def __init__(self, cfg: ModelConfig, flags: RuntimeFlags = RuntimeFlags()):
        self.cfg = cfg
        self.flags = flags
        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            self.n_groups, self.n_tail = divmod(cfg.num_layers, len(pat))
        else:
            self.n_groups, self.n_tail = cfg.num_layers, 0

    # ------------------------------------------------------------------
    # Block kinds
    # ------------------------------------------------------------------
    @property
    def block_kind(self) -> str:
        c = self.cfg
        if c.family == "ssm":
            return "ssm"
        if c.moe is not None:
            return "moe"
        if c.attention == "mla":
            return "mla"
        return "dense"

    def _init_block(self, key, kind: str) -> dict:
        cfg, dtype = self.cfg, self.flags.dtype
        k1, k2 = jax.random.split(key)
        d = cfg.d_model
        if kind == "ssm":
            return {"ln1": L.init_rmsnorm(d), "ssm": SSM.init_ssm(k1, cfg, dtype)}
        if kind == "rec":
            return {"ln1": L.init_rmsnorm(d), "rec": RG.init_rglru_block(k1, cfg, dtype),
                    "ln2": L.init_rmsnorm(d), "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype)}
        if kind == "mla":
            return {"ln1": L.init_rmsnorm(d), "attn": L.init_mla(k1, cfg, dtype),
                    "ln2": L.init_rmsnorm(d), "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype)}
        if kind == "moe":
            return {"ln1": L.init_rmsnorm(d), "attn": L.init_attention(k1, cfg, dtype),
                    "ln2": L.init_rmsnorm(d), "moe": MOE.init_moe(k2, cfg, dtype)}
        # dense (also the attention block of hybrids)
        return {"ln1": L.init_rmsnorm(d), "attn": L.init_attention(k1, cfg, dtype),
                "ln2": L.init_rmsnorm(d), "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype)}

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg, dtype = self.cfg, self.flags.dtype
        k_emb, k_blocks, k_head, k_tail = jax.random.split(key, 4)
        d = cfg.d_model
        params = {
            "embed": {"tok": (jax.random.normal(k_emb, (cfg.vocab_size, d))
                              * (1.0 / math.sqrt(d))).astype(dtype)},
            "final_norm": L.init_rmsnorm(d),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = (jax.random.normal(k_head, (d, cfg.vocab_size))
                                 * (1.0 / math.sqrt(d))).astype(dtype)
        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            gkeys = jax.random.split(k_blocks, self.n_groups)
            groups = []
            for gk in gkeys:
                bkeys = jax.random.split(gk, len(pat))
                groups.append({f"b{i}_{kind}": self._init_block(bk, kind)
                               for i, (kind, bk) in enumerate(zip(pat, bkeys))})
            params["blocks"] = _stack(groups)
            if self.n_tail:
                tkeys = jax.random.split(k_tail, self.n_tail)
                params["tail"] = _stack(
                    [self._init_block(tk, pat[i % len(pat)])
                     for i, tk in enumerate(tkeys)])
        else:
            bkeys = jax.random.split(k_blocks, cfg.num_layers)
            params["blocks"] = _stack(
                [self._init_block(bk, self.block_kind) for bk in bkeys])
        return params

    # ------------------------------------------------------------------
    # Single-block application (dense sequence)
    # ------------------------------------------------------------------
    def apply_block_dense(self, bp: dict, x, kind: str, *, return_cache: bool,
                          window=None, positions=None):
        cfg, f = self.cfg, self.flags
        cache = None
        if kind == "ssm":
            h, cache = SSM.apply_ssm_dense(
                bp["ssm"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg)
            x = x + h
        elif kind == "rec":
            h, cache = RG.apply_rglru_dense(
                bp["rec"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg)
            x = x + h
            x = x + L.apply_mlp(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
        elif kind == "mla":
            h, cache = L.apply_mla_dense(
                bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg,
                chunk=f.attn_chunk, positions=positions, window=window,
                absorbed=f.mla_absorbed)
            x = x + h
            x = x + L.apply_mlp(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
        else:
            h, kv = L.apply_attention_dense(
                bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg,
                window=window, chunk=f.attn_chunk, positions=positions)
            cache = {"k": kv[0], "v": kv[1]}
            x = x + h
            if "moe" in bp:
                h, aux = MOE.apply_moe(bp["moe"],
                                       L.rms_norm(x, bp["ln2"], cfg.norm_eps),
                                       cfg, group_rows=f.moe_group_rows)
                x = x + h
                cache = (cache, aux)
            else:
                x = x + L.apply_mlp(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
        if not return_cache and not (isinstance(cache, tuple)):
            cache = None
        return x, cache

    def apply_block_decode(self, bp: dict, x, cache, pos, kind: str, *,
                           window=None, slots=None, ctx=None):
        """One decode step for one block. With ``slots`` ((B,) int32) the
        cache is a persistent slot arena (leading axis n_slots >= B): rows
        are gathered / scattered in-place on device and the full updated
        arena is returned (attention/MLA do the indexed update natively).
        ``ctx`` (static int) bounds attention reads to a context bucket —
        see ``layers.apply_attention_decode``."""
        cfg, f = self.cfg, self.flags
        if kind == "ssm":
            h, rows = SSM.apply_ssm_decode(
                bp["ssm"], L.rms_norm(x, bp["ln1"], cfg.norm_eps),
                _gather_rows(cache, slots), cfg)
            return x + h, _scatter_rows(cache, rows, slots)
        if kind == "rec":
            h, rows = RG.apply_rglru_decode(
                bp["rec"], L.rms_norm(x, bp["ln1"], cfg.norm_eps),
                _gather_rows(cache, slots), cfg)
            x = x + h
            x = x + L.apply_mlp(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
            return x, _scatter_rows(cache, rows, slots)
        if kind == "mla":
            h, cache = L.apply_mla_decode(
                bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cache, pos,
                cfg, window=window, slots=slots, ctx=ctx)
            x = x + h
            x = x + L.apply_mlp(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
            return x, cache
        h, cache = L.apply_attention_decode(
            bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cache, pos, cfg,
            window=window, grouped=f.grouped_decode,
            use_pallas=f.pallas_decode, slots=slots, ctx=ctx)
        x = x + h
        if "moe" in bp:
            y, _aux = MOE.apply_moe(bp["moe"],
                                    L.rms_norm(x, bp["ln2"], cfg.norm_eps)[:, None, :],
                                    cfg, group_rows=f.moe_group_rows)
            x = x + y[:, 0, :]
        else:
            x = x + L.apply_mlp(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
        return x, cache

    # ------------------------------------------------------------------
    # Stacked-span application (run-fused serving dispatch)
    # ------------------------------------------------------------------
    def apply_span_decode(self, stacked_bp, x, flat_arena, pos, kind, *,
                          offs, window=None, slots=None, ctx=None):
        """One decode step through a *span* of same-kind layers as a single
        ``lax.scan`` over stacked per-layer params.

        ``flat_arena`` is the span's slot arena with the layer axis FOLDED
        into the slot axis — leaves are ``(span_len * n_slots, ...)`` and
        layer k's batch rows live at ``slots + offs[k]`` (``offs[k] =
        k * n_slots``). The arena rides the scan CARRY, so XLA aliases it
        in place across layers: each step only gathers the B live rows it
        reads and scatters the rows it writes — no per-layer arena slice
        is ever materialized (scanning the arena as xs/ys would copy every
        layer's full cache twice per step).
        """
        def body(carry, xs):
            x, arena = carry
            bp, off = xs
            x, arena = self.apply_block_decode(bp, x, arena, pos, kind,
                                               window=window,
                                               slots=slots + off, ctx=ctx)
            return (x, arena), None

        (x, flat_arena), _ = jax.lax.scan(body, (x, flat_arena),
                                          (stacked_bp, offs))
        return x, flat_arena

    def apply_span_prefill(self, stacked_bp, flat_arena, x, kind, *,
                           offs, window=None, positions=None, write=None):
        """Full-prompt prefill through a span of same-kind layers in one
        scanned dispatch (arena flat-layout as in ``apply_span_decode``).
        ``write(flat_arena, cache, row_idx) -> flat_arena`` stores each
        layer's prefill cache into its members' arena rows inside the scan
        body (the caller owns the slot layout)."""
        def body(carry, xs):
            x, arena = carry
            bp, off = xs
            x, cache = self.apply_block_dense(bp, x, kind, return_cache=True,
                                              window=window,
                                              positions=positions)
            if isinstance(cache, tuple):          # moe: (kv_cache, aux)
                cache = cache[0]
            if write is not None:
                arena = write(arena, cache, off)
            return (x, arena), None

        (x, flat_arena), _ = jax.lax.scan(body, (x, flat_arena),
                                          (stacked_bp, offs))
        return x, flat_arena

    # ------------------------------------------------------------------
    # Stacked execution
    # ------------------------------------------------------------------
    def _block_kinds_and_windows(self, decode_window):
        """Per-pattern-position (kind, window) for hybrid; scalar otherwise."""
        cfg = self.cfg
        if cfg.hybrid is None:
            return self.block_kind, decode_window
        out = []
        for kind in cfg.hybrid.block_pattern:
            out.append((kind if kind == "rec" else "dense",
                        cfg.hybrid.local_window if kind == "attn" else None))
        return out, None

    def _run_dense(self, params, x, *, return_cache: bool, window=None,
                   positions=None):
        cfg, f = self.cfg, self.flags

        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            kinds = [("rec", None) if k == "rec"
                     else ("dense", cfg.hybrid.local_window) for k in pat]

            def group_body(x, gp):
                caches = {}
                for i, (kind, win) in enumerate(kinds):
                    key = f"b{i}_{pat[i]}"
                    x, c = self.apply_block_dense(gp[key], x, kind,
                                                  return_cache=return_cache,
                                                  window=win, positions=positions)
                    caches[key] = c
                return x, caches

            x, caches = self._scan_blocks(group_body, x, params["blocks"])
            tail_caches = []
            if self.n_tail:
                for i in range(self.n_tail):
                    kind, win = kinds[i % len(kinds)]
                    bp = _index(params["tail"], i)
                    x, c = self.apply_block_dense(bp, x, kind,
                                                  return_cache=return_cache,
                                                  window=win, positions=positions)
                    tail_caches.append(c)
            return x, (caches, tail_caches), jnp.float32(0.0)

        kind = self.block_kind

        def body(x, bp):
            x, cache = self.apply_block_dense(bp, x, kind,
                                              return_cache=return_cache,
                                              window=window, positions=positions)
            aux = jnp.float32(0.0)
            if isinstance(cache, tuple):      # moe: (kv_cache, aux)
                cache, aux = cache
                if not return_cache:
                    cache = None
            return x, (cache, aux)

        x, (caches, auxs) = self._scan_blocks(body, x, params["blocks"])
        aux = jnp.sum(auxs) if auxs is not None else jnp.float32(0.0)
        return x, (caches, []), aux

    def _remat(self, body):
        if self.flags.remat_policy == "dots":
            return jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        return jax.checkpoint(body)

    def _scan_blocks(self, body, x, blocks):
        f = self.flags
        if f.use_scan:
            fn = self._remat(body) if f.remat else body
            return jax.lax.scan(fn, x, blocks, unroll=f.scan_unroll)
        fn = self._remat(body) if f.remat else body
        n = jax.tree.leaves(blocks)[0].shape[0]
        ys = []
        for i in range(n):
            x, y = fn(x, _index(blocks, i))
            ys.append(y)
        stacked = jax.tree.map(lambda *v: jnp.stack(v), *ys) if ys else None
        return x, stacked

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------
    def embed(self, params, tokens):
        emb = jnp.take(params["embed"]["tok"], tokens, axis=0)
        return emb.astype(self.flags.dtype)

    def unembed(self, params, x):
        """x: (..., d) -> logits (..., V) sharded over vocab."""
        cfg = self.cfg
        if cfg.tie_embeddings:
            table = params["embed"]["tok"]                  # (V, d)
            table = shard(table, "vocab", None)             # reshard for head
            return jnp.einsum("...d,vd->...v", x, table)
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"])
        if logits.ndim == 3:
            logits = shard(logits, "batch", "seq", "vocab")
        return logits

    # ------------------------------------------------------------------
    # Public steps
    # ------------------------------------------------------------------
    def loss(self, params, batch) -> tuple:
        """batch: {"tokens": (B,S), "targets": (B,S), ["prefix": (B,P,d)]}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed(params, tokens)
        prefix = batch.get("prefix")
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        x = shard(x, "batch", "act_seq", "embed")
        S_total = x.shape[1]
        positions = jnp.arange(S_total)[None, :]
        x, _, aux = self._run_dense(params, x, return_cache=False,
                                    positions=positions)
        if prefix is not None:
            x = x[:, prefix.shape[1]:, :]
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, x).astype(jnp.float32)
        targets = batch["targets"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        tgt = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
        ce = jnp.mean(lse - tgt)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    def prefill(self, params, tokens, prefix=None, max_len: Optional[int] = None):
        """Returns (last-token logits (B, V), cache)."""
        cfg, f = self.cfg, self.flags
        x = self.embed(params, tokens)
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        x = shard(x, "batch", "act_seq", "embed")
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        x, caches, _ = self._run_dense(params, x, return_cache=True,
                                       window=f.window, positions=positions)
        x = L.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, x)
        return logits, caches

    def decode_step(self, params, cache, token, pos):
        """token: (B,) int32; pos: (B,) int32 ragged positions.

        Returns (logits (B, V), new_cache).
        """
        cfg, f = self.cfg, self.flags
        x = self.embed(params, token)
        x = shard(x, "batch", "embed")

        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            kinds = [("rec", None) if k == "rec"
                     else ("dense", cfg.hybrid.local_window) for k in pat]

            def group_body(x, blk_cache):
                gp, gc = blk_cache
                new_c = {}
                for i, (kind, win) in enumerate(kinds):
                    key = f"b{i}_{pat[i]}"
                    x, c = self.apply_block_decode(gp[key], x, gc[key], pos,
                                                   kind, window=win)
                    new_c[key] = c
                return x, new_c

            group_caches, tail_caches = cache
            if self.flags.use_scan:
                x, new_caches = jax.lax.scan(group_body, x,
                                             (params["blocks"], group_caches),
                                             unroll=f.scan_unroll)
            else:
                n = self.n_groups
                ys = []
                for i in range(n):
                    x, y = group_body(x, (_index(params["blocks"], i),
                                          _index(group_caches, i)))
                    ys.append(y)
                new_caches = jax.tree.map(lambda *v: jnp.stack(v), *ys)
            new_tail = []
            for i in range(self.n_tail):
                kind, win = kinds[i % len(kinds)]
                x, c = self.apply_block_decode(_index(params["tail"], i), x,
                                               tail_caches[i], pos, kind,
                                               window=win)
                new_tail.append(c)
            new_cache = (new_caches, new_tail)
        else:
            kind = self.block_kind
            window = f.window

            def body(x, blk_cache):
                bp, c = blk_cache
                x, nc = self.apply_block_decode(bp, x, c, pos, kind,
                                                window=window)
                return x, nc

            group_caches, _tail = cache
            if self.flags.use_scan:
                x, new_caches = jax.lax.scan(body, x,
                                             (params["blocks"], group_caches),
                                             unroll=f.scan_unroll)
            else:
                n = self.cfg.num_layers
                ys = []
                for i in range(n):
                    x, y = body(x, (_index(params["blocks"], i),
                                    _index(group_caches, i)))
                    ys.append(y)
                new_caches = jax.tree.map(lambda *v: jnp.stack(v), *ys)
            new_cache = (new_caches, [])

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, x)
        return logits, new_cache

    # ------------------------------------------------------------------
    # Cache construction
    # ------------------------------------------------------------------
    def _init_layer_cache(self, kind: str, batch: int, max_len: int, window):
        cfg, dtype = self.cfg, self.flags.dtype
        if kind == "ssm":
            return SSM.init_ssm_cache(cfg, batch, dtype)
        if kind == "rec":
            return RG.init_rglru_cache(cfg, batch, dtype)
        if kind == "mla":
            return L.init_mla_cache(cfg, batch, max_len, dtype, window=window)
        return L.init_attention_cache(cfg, batch, max_len, dtype,
                                      window=window,
                                      quant=self.flags.kv_quant)

    def init_cache(self, batch: int, max_len: int):
        cfg, f = self.cfg, self.flags
        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            kinds = [("rec", None) if k == "rec"
                     else ("dense", cfg.hybrid.local_window) for k in pat]
            one = {f"b{i}_{pat[i]}": self._init_layer_cache(kind, batch, max_len, win)
                   for i, (kind, win) in enumerate(kinds)}
            groups = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_groups, *x.shape)), one)
            tail = [self._init_layer_cache(kinds[i % len(kinds)][0], batch,
                                           max_len, kinds[i % len(kinds)][1])
                    for i in range(self.n_tail)]
            return (groups, tail)
        kind = self.block_kind
        one = self._init_layer_cache(kind, batch, max_len, f.window)
        caches = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers, *x.shape)), one)
        return (caches, [])
