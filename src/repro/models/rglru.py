"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t), r_t/i_t sigmoid gates,
c = 8. The full-sequence path uses ``jax.lax.associative_scan`` (log-depth;
TPU-friendly, exactly counted by cost analysis — DESIGN.md §3).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding import shard

_C = 8.0


def init_rglru_block(key, cfg, dtype) -> dict:
    h = cfg.hybrid
    d = cfg.d_model
    w = h.lru_width or d
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    sw = 1.0 / math.sqrt(w)
    # a initialised so that a^c in [0.9, 0.999]
    a_init = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(a_init) / _C))   # inverse softplus
    return {
        "w_gate_branch": (jax.random.normal(ks[0], (d, w)) * s).astype(dtype),
        "w_rec_branch": (jax.random.normal(ks[1], (d, w)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (h.conv_width, w)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": (jax.random.normal(ks[3], (w, w)) * sw).astype(dtype),
        "w_i": (jax.random.normal(ks[5], (w, w)) * sw).astype(dtype),
        "lambda": lam,
        "w_out": (jax.random.normal(ks[6], (w, d)) * sw).astype(dtype),
    }


def _gates(p, x):
    """x: (..., w) conv output -> (log_a, gated_input) in f32."""
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a2 = jnp.exp(2 * log_a)
    gated = jnp.sqrt(jnp.maximum(1 - a2, 1e-6)) * i * x.astype(jnp.float32)
    return log_a, gated


def _causal_conv(x, w, b):
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[W - 1 - i]
    return out + b


def apply_rglru_dense(p: dict, x_in: jax.Array, cfg):
    """Full-sequence recurrent block. x_in: (B, S, d) -> (y, cache)."""
    gate = jax.nn.gelu(x_in @ p["w_gate_branch"])
    rec = x_in @ p["w_rec_branch"]
    rec = _causal_conv(rec, p["conv_w"], p["conv_b"])
    rec = shard(rec, "batch", "seq", "lru")
    log_a, gated = _gates(p, rec)

    def combine(a, b):
        la, ha = a
        lb, hb = b
        return la + lb, ha * jnp.exp(lb) + hb

    _, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    y = (h.astype(x_in.dtype) * gate) @ p["w_out"]
    W = p["conv_w"].shape[0]
    conv_cache = (x_in @ p["w_rec_branch"])[:, -(W - 1):, :]
    cache = {"state": h[:, -1], "conv": conv_cache}
    return shard(y, "batch", "act_seq", "embed"), cache


def apply_rglru_decode(p: dict, x_in: jax.Array, cache: dict, cfg):
    """Single-step update. x_in: (B, d)."""
    gate = jax.nn.gelu(x_in @ p["w_gate_branch"])
    rec_new = x_in @ p["w_rec_branch"]
    conv_in = jnp.concatenate([cache["conv"], rec_new[:, None]], axis=1)
    rec = jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"]) + p["conv_b"]
    log_a, gated = _gates(p, rec)
    h = cache["state"] * jnp.exp(log_a) + gated
    y = (h.astype(x_in.dtype) * gate) @ p["w_out"]
    new_conv = jnp.concatenate([cache["conv"][:, 1:], rec_new[:, None]], axis=1)
    return y, {"state": h, "conv": new_conv}


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    h = cfg.hybrid
    w = h.lru_width or cfg.d_model
    return {
        "state": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, h.conv_width - 1, w), dtype),
    }
