"""Core transformer layers: RMSNorm, RoPE, SwiGLU MLP, GQA + MLA attention.

All layers are pure functions over explicit parameter pytrees so they can be
(a) scanned over stacked layer params, (b) executed node-at-a-time by the
LazyBatching engine, and (c) lowered under pjit with logical-axis sharding
hints (see ``repro.sharding``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..sharding import shard

# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(x: jax.Array, p: dict, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., H, D); positions broadcastable to x's S axis.

    positions: (..., S) int32 absolute positions.
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    return {
        "w_gate": (jax.random.normal(k1, (d, ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (ff, d)) * s_out).astype(dtype),
    }


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    if h.ndim == 3:
        h = shard(h, "batch", "seq", "ffn")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, h, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h, hd, d)) * (1.0 / math.sqrt(h * hd))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def pick_chunk(s: int, target: int = 2048) -> int:
    """Largest divisor of ``s`` that is <= target."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def _qkv(p: dict, x: jax.Array, cfg, positions: jax.Array):
    """Project to q/k/v, apply RoPE; k/v repeated to full head count."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    return q, k, v


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    kvh = k.shape[-2]
    if kvh == num_heads:
        return k
    return jnp.repeat(k, num_heads // kvh, axis=-2)


def chunked_causal_attention(q, k, v, *, window: Optional[int] = None,
                             chunk: int = 2048, q_offset: int = 0) -> jax.Array:
    """Blockwise causal self-attention without materializing (S, S) scores.

    q: (B, S, H, D); k, v: (B, T, H, D) with T >= S and
    q position i corresponds to key position ``q_offset + i``.
    The chunk loop is a *static* python loop: slices are static, HLO contains
    one block per chunk (counted exactly by cost analysis — DESIGN.md §3).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    chunk = pick_chunk(S, chunk)
    outs = []
    for i in range(S // chunk):
        q_i = q[:, i * chunk:(i + 1) * chunk]
        hi = q_offset + (i + 1) * chunk           # exclusive key bound
        lo = 0 if window is None else max(0, hi - chunk - window)
        k_i = k[:, lo:hi]
        v_i = v[:, lo:hi]
        scores = jnp.einsum("bshd,bthd->bhst", q_i, k_i).astype(jnp.float32) * scale
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        kpos = lo + jnp.arange(hi - lo)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        outs.append(jnp.einsum("bhst,bthd->bshd", probs, v_i))
    return jnp.concatenate(outs, axis=1)


def apply_attention_dense(p: dict, x: jax.Array, cfg, *,
                          window: Optional[int] = None,
                          chunk: int = 2048,
                          positions: Optional[jax.Array] = None):
    """Full-sequence self-attention (train / prefill).

    Returns (out, (k, v)) so prefill can keep the cache.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    kf = repeat_kv(k, cfg.num_heads)
    vf = repeat_kv(v, cfg.num_heads)
    out = chunked_causal_attention(q, kf, vf, window=window, chunk=chunk)
    y = jnp.einsum("bshd,hdk->bsk", out, p["wo"])
    return shard(y, "batch", "act_seq", "embed"), (k, v)


def apply_attention_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                           cfg, *, window: Optional[int] = None,
                           grouped: bool = False,
                           use_pallas: bool = False,
                           slots: Optional[jax.Array] = None,
                           ctx: Optional[int] = None):
    """Single-token decode with ragged per-row positions.

    x: (B, d); pos: (B,) int32 — the index of the token being generated
    (ragged across the batch: lazily merged requests have different
    progress). cache: {"k": (B, T, KV, D), "v": ...} where T is either the
    max context or the sliding window size (ring buffer when ``window``).

    ``slots`` ((B,) int32, optional): the cache is a persistent slot ARENA
    of leading size n_slots >= B and batch row i lives in arena row
    ``slots[i]``. The new k/v token is scattered in-place into the arena
    (``.at[slots, pos]``), attention reads the gathered rows (or, on the
    Pallas path, reads the arena directly via slot-indexed BlockSpecs), and
    the returned cache is the FULL updated arena — no per-request
    stack/unstack, no host round-trips. Batch-bucketed dispatch pads rows
    with the out-of-bounds slot ``n_slots``: their scatters are DROPPED
    (mode="drop" — a padded row must never corrupt a live slot) while
    gathers/kernel reads use indices clamped in-bounds, so padded rows
    read some live row and produce garbage that the caller discards.

    ``grouped`` (§Perf beyond-paper optimization): GQA scores computed per
    KV group via a batched einsum — no ``repeat_kv`` materialization of the
    H/KV-times-inflated cache, and the contraction batches over the kv-head
    dim so a kv-sharded cache keeps the whole attention local per device.

    ``ctx`` (STATIC context bound, arena path only): gather/score only the
    first ``ctx`` time rows instead of the full ``max_len`` — the caller
    passes a power-of-two bucket covering ``max(pos) + 1``, so the per-token
    gather and attention cost scale with actual context, not arena
    capacity. Rows beyond each row's ``pos`` are masked exactly as before;
    bit-identical to the unbounded read.
    """
    B, d = x.shape
    T = cache["k"].shape[1]
    if ctx is not None and (window is not None or slots is None
                            or ctx >= T):
        ctx = None                                # bound only the arena path
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    slot = pos % T if window is not None else pos
    row_idx = slots if slots is not None else jnp.arange(B)
    if slots is None:
        rows = lambda l: l
    else:
        gslots = jnp.minimum(slots, cache["k"].shape[0] - 1)
        rows = ((lambda l: l[gslots]) if ctx is None
                else (lambda l: l[gslots, :ctx]))
    quant = "k_scale" in cache
    if quant:
        kq, ks = _quantize_rows(k)
        vq, vs = _quantize_rows(v)
        new_cache = {
            "k": cache["k"].at[row_idx, slot].set(kq, mode="drop"),
            "v": cache["v"].at[row_idx, slot].set(vq, mode="drop"),
            "k_scale": cache["k_scale"].at[row_idx, slot].set(ks, mode="drop"),
            "v_scale": cache["v_scale"].at[row_idx, slot].set(vs, mode="drop"),
        }
        ck = (rows(new_cache["k"]).astype(x.dtype)
              * rows(new_cache["k_scale"])[..., None].astype(x.dtype))
        cv = (rows(new_cache["v"]).astype(x.dtype)
              * rows(new_cache["v_scale"])[..., None].astype(x.dtype))
    else:
        new_cache = {"k": cache["k"].at[row_idx, slot].set(k, mode="drop"),
                     "v": cache["v"].at[row_idx, slot].set(v, mode="drop")}

    scale = 1.0 / math.sqrt(cfg.head_dim)
    t_idx = jnp.arange(T if ctx is None else ctx)[None, :]
    if window is None:
        valid = t_idx <= pos[:, None]
    else:
        # ring buffer: slots [0, min(pos+1, T)) hold live tokens
        valid = t_idx < jnp.minimum(pos[:, None] + 1, T)

    if use_pallas and window is None and not quant:
        # TPU target path: ONE ragged-attention kernel for the whole merged
        # sub-batch (per-row lengths = pos + 1); slot indirection happens
        # inside the kernel's index maps. interpret=True on CPU.
        from ..kernels.ragged_decode_attn import ragged_decode_attention
        out = ragged_decode_attention(q, new_cache["k"], new_cache["v"],
                                      pos + 1,
                                      slots=None if slots is None else gslots)
        y = jnp.einsum("bhk,hkd->bd", out, p["wo"])
        return y, new_cache

    if not quant:
        ck = rows(new_cache["k"])
        cv = rows(new_cache["v"])

    if grouped:
        KV, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
        qg = q.reshape(B, KV, G, cfg.head_dim)
        scores = jnp.einsum("bkgh,btkh->bkgt", qg, ck).astype(jnp.float32)
        scores = scores * scale
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgt,btkh->bkgh", probs, cv)
        out = out.reshape(B, cfg.num_heads, cfg.head_dim)
    else:
        kf = repeat_kv(ck, cfg.num_heads)
        vf = repeat_kv(cv, cfg.num_heads)
        scores = jnp.einsum("bhk,bthk->bht", q, kf).astype(jnp.float32) * scale
        scores = jnp.where(valid[:, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bht,bthk->bhk", probs, vf)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])
    return y, new_cache


def init_attention_cache(cfg, batch: int, max_len: int, dtype,
                         window: Optional[int] = None,
                         quant: bool = False) -> dict:
    T = min(max_len, window) if window else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if quant:
        # §Perf beyond-paper: int8 symmetric per-(token, kv-head) quantized
        # cache — halves the decode-serving HBM capacity and read traffic
        # (the dominant roofline term at decode_32k).
        return {
            "k": jnp.zeros((batch, T, kv, hd), jnp.int8),
            "v": jnp.zeros((batch, T, kv, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, T, kv), jnp.float32),
            "v_scale": jnp.zeros((batch, T, kv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, T, kv, hd), dtype),
        "v": jnp.zeros((batch, T, kv, hd), dtype),
    }


def _quantize_rows(x: jax.Array):
    """x: (..., D) -> (int8 values, f32 scale over the last dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "wq_a": (jax.random.normal(ks[0], (d, m.q_lora_rank)) * s).astype(dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank),
        "wq_b": (jax.random.normal(ks[1], (m.q_lora_rank, h, qk))
                 * (1 / math.sqrt(m.q_lora_rank))).astype(dtype),
        "wkv_a": (jax.random.normal(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)) * s).astype(dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
        "wkv_b": (jax.random.normal(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim))
                  * (1 / math.sqrt(m.kv_lora_rank))).astype(dtype),
        "wo": (jax.random.normal(ks[4], (h, m.v_head_dim, d))
               * (1 / math.sqrt(h * m.v_head_dim))).astype(dtype),
    }


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    ql = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def apply_mla_dense(p: dict, x: jax.Array, cfg, *, chunk: int = 2048,
                    positions: Optional[jax.Array] = None,
                    window: Optional[int] = None,
                    absorbed: bool = False):
    """Full-sequence MLA; returns (out, cache={"ckv", "krope"}).

    ``absorbed`` (§Perf beyond-paper optimization): attention runs in the
    compressed latent space — q is absorbed through wkv_b so the per-chunk
    K-side read is the (T, R + P) latent cache instead of the
    (T, H, qk)-materialized keys (H·qk / (R+P) ≈ 13x traffic reduction for
    MiniCPM3), and no per-head K/V is ever materialized in HBM.
    """
    m = cfg.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    kv = x @ p["wkv_a"]
    ckv = rms_norm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)[..., 0, :]

    if absorbed:
        wkv_b_k = p["wkv_b"][..., :m.qk_nope_head_dim]    # (R, H, nope)
        wkv_b_v = p["wkv_b"][..., m.qk_nope_head_dim:]    # (R, H, v)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wkv_b_k)   # (B,S,H,R)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        c = pick_chunk(S, chunk)
        outs = []
        for i in range(S // c):
            hi = (i + 1) * c
            lo = 0 if window is None else max(0, hi - c - window)
            ql = q_lat[:, i * c:hi]
            qr = q_rope[:, i * c:hi]
            scores = (jnp.einsum("bshr,btr->bhst", ql, ckv[:, lo:hi])
                      + jnp.einsum("bshp,btp->bhst", qr, k_rope[:, lo:hi]))
            scores = scores.astype(jnp.float32) * scale
            qpos = i * c + jnp.arange(c)
            kpos = lo + jnp.arange(hi - lo)
            mask = kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            scores = jnp.where(mask[None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            ctx = jnp.einsum("bhst,btr->bshr", probs, ckv[:, lo:hi])
            outs.append(jnp.einsum("bshr,rhv->bshv", ctx, wkv_b_v))
        out = jnp.concatenate(outs, axis=1)
    else:
        kvb = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"])
        k_nope = kvb[..., :m.qk_nope_head_dim]
        value = kvb[..., m.qk_nope_head_dim:]
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope[:, :, None, :],
                                              (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
        # pad value head dim up to qk dim so we can reuse the chunked kernel
        out = chunked_causal_attention(q, k, value, chunk=chunk, window=window)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return shard(y, "batch", "act_seq", "embed"), {"ckv": ckv, "krope": k_rope}


def apply_mla_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array, cfg,
                     *, window: Optional[int] = None,
                     slots: Optional[jax.Array] = None,
                     ctx: Optional[int] = None):
    """Absorbed-matmul MLA decode over the compressed latent cache.

    cache: {"ckv": (B, T, R), "krope": (B, T, P)}. With ``slots`` the cache
    is a persistent (n_slots, T, ·) arena and batch row i lives in arena
    row ``slots[i]`` (see ``apply_attention_decode``); the full updated
    arena is returned. ``ctx`` bounds the gathered/scored time rows to a
    static context bucket exactly as in ``apply_attention_decode``.
    """
    m = cfg.mla
    B, d = x.shape
    T = cache["ckv"].shape[1]
    if ctx is not None and (window is not None or slots is None
                            or ctx >= T):
        ctx = None
    q_nope, q_rope = _mla_q(p, x[:, None], cfg, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]           # (B, H, ·)
    kv = x @ p["wkv_a"]
    ckv_t = rms_norm(kv[:, :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope_t = apply_rope(kv[:, None, None, m.kv_lora_rank:], pos[:, None],
                         cfg.rope_theta)[:, 0, 0]
    slot = pos % T if window is not None else pos
    row_idx = slots if slots is not None else jnp.arange(B)
    ckv_full = cache["ckv"].at[row_idx, slot].set(ckv_t, mode="drop")
    krope_full = cache["krope"].at[row_idx, slot].set(krope_t, mode="drop")
    if slots is None:
        ckv, krope = ckv_full, krope_full
    else:
        # clamp for the gather: batch-bucket padding rows carry the
        # out-of-bounds slot n_slots (scatter dropped above)
        gslots = jnp.minimum(slots, ckv_full.shape[0] - 1)
        if ctx is None:
            ckv, krope = ckv_full[gslots], krope_full[gslots]
        else:
            ckv, krope = ckv_full[gslots, :ctx], krope_full[gslots, :ctx]

    wkv_b_k = p["wkv_b"][..., :m.qk_nope_head_dim]        # (R, H, nope)
    wkv_b_v = p["wkv_b"][..., m.qk_nope_head_dim:]        # (R, H, v)
    # absorb q into latent space: (B,H,R)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, wkv_b_k)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bhr,btr->bht", q_lat, ckv)
              + jnp.einsum("bhp,btp->bht", q_rope, krope)).astype(jnp.float32) * scale
    t_idx = jnp.arange(T if ctx is None else ctx)[None, :]
    if window is None:
        valid = t_idx <= pos[:, None]
    else:
        valid = t_idx < jnp.minimum(pos[:, None] + 1, T)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bht,btr->bhr", probs, ckv)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wkv_b_v)
    y = jnp.einsum("bhv,hvd->bd", out, p["wo"])
    return y, {"ckv": ckv_full, "krope": krope_full}


def init_mla_cache(cfg, batch: int, max_len: int, dtype,
                   window: Optional[int] = None) -> dict:
    m = cfg.mla
    T = min(max_len, window) if window else max_len
    return {
        "ckv": jnp.zeros((batch, T, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, T, m.qk_rope_head_dim), dtype),
    }
