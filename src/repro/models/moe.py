"""Top-k Mixture-of-Experts with sort-based dispatch.

TPU-adapted design (DESIGN.md §3): instead of GShard's O(T·E·C) one-hot
dispatch einsums (memory- and FLOP-prohibitive at our token counts) we use a
*sort-based* dispatch inside each token group:

  1. route: top-k experts per token (softmax over the selected logits),
  2. sort the (token, expert) pairs by expert id (stable argsort),
  3. compute each pair's rank within its expert run (searchsorted on the
     sorted ids — O(n log n), no O(T·E) one-hot),
  4. scatter token vectors into an (E, C) capacity-bounded buffer,
  5. batched expert FFN: one einsum over all experts (MXU-friendly),
  6. gather back and combine with routing weights.

Groups are rows of the leading batch axis, which is sharded over `data`,
so dispatch is fully local per device — no all-to-all in the baseline
(an expert-parallel all-to-all variant is a §Perf hillclimb).

FLOP honesty: expert compute is E·C·(3·d·ff) with C = ceil(T·k/E · cf),
i.e. active-FLOPs × capacity factor — no dense-all-experts waste.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..sharding import shard


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, d)) * s_out).astype(dtype),
    }


def _dispatch_indices(expert_ids: jax.Array, num_experts: int, capacity: int):
    """expert_ids: (n,) int32 flat (token·k) expert assignments.

    Returns (order, slot, keep): token-pair order sorted by expert, each
    pair's slot within its expert's capacity buffer, and a keep mask for
    pairs that fit under the capacity bound.
    """
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_eid = expert_ids[order]
    # rank of each element within its expert run
    first = jnp.searchsorted(sorted_eid, sorted_eid, side="left")
    rank = jnp.arange(n) - first
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity - 1)
    return order, sorted_eid, slot, keep


def apply_moe(p: dict, x: jax.Array, cfg, *, group_rows: int = 1):
    """x: (B, S, d) -> (y, aux_loss).

    ``group_rows`` merges that many batch rows into one routing group
    (decode uses larger groups so capacity stays >= 1 useful slot).
    """
    m = cfg.moe
    B, S, d = x.shape
    e, k = m.num_experts, m.experts_per_token
    g = max(1, min(group_rows, B))
    G = B // g
    t = g * S                                  # tokens per group
    cap = max(1, math.ceil(t * k / e * m.capacity_factor))

    xg = x.reshape(G, t, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)               # (G, t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    def route_one(xt, eids, wts):
        # xt: (t, d); eids/wts: (t, k)
        flat_e = eids.reshape(-1)
        order, sorted_eid, slot, keep = _dispatch_indices(flat_e, e, cap)
        src = order // k
        buf = jnp.zeros((e, cap, d), xt.dtype)
        buf = buf.at[sorted_eid, slot].add(
            jnp.where(keep[:, None], xt[src], 0))
        return buf, (order, sorted_eid, slot, keep, src)

    buf, route = jax.vmap(route_one, in_axes=(0, 0, 0))(xg, top_e, top_w)
    buf = shard(buf, "batch_nopod", "experts", None, "embed")

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = shard(h, "batch_nopod", "experts", None, "expert_ffn")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    # §Perf: "moe_out" defaults to replicated (baseline all-reduce of the
    # (e, cap, d) buffer); mapping it to "model" in the rules turns the TP
    # sum into a reduce-scatter over d — the combine below is linear, so
    # the deferred gather happens on the much smaller (t, d) output.
    out_buf = shard(out_buf, "batch_nopod", "experts", None, "moe_out")

    def combine_one(ob, wts, r):
        order, sorted_eid, slot, keep, src = r
        vals = ob[sorted_eid, slot] * jnp.where(keep[:, None], 1.0, 0.0).astype(ob.dtype)
        w_sorted = wts.reshape(-1)[order].astype(ob.dtype)
        y = jnp.zeros((t, d), ob.dtype)
        return y.at[src].add(vals * w_sorted[:, None])

    y = jax.vmap(combine_one)(out_buf, top_w, route)
    y = y.reshape(B, S, d)

    # Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y, aux
