"""Analytic per-node FLOPs / bytes cost model.

Used by three consumers:
  * the NPU latency model (``repro.serving.npu_model``) — per-node latency
    estimation, exactly the paper's ``NodeLatency(n)`` lookup table,
  * the SLA-aware slack predictor (Algorithm 1),
  * the roofline analysis (MODEL_FLOPS = 6·N·D terms and cross-checks).

All numbers are *forward* costs for one node (layer) at a given batch /
sequence / context. Weight bytes are separated from activation bytes because
batching amortizes weight traffic — the effect that produces the paper's
Fig. 3 throughput curve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from ..configs.base import ModelConfig


@dataclass(frozen=True)
class NodeCost:
    name: str
    flops: float          # per execution of this node (whole batch)
    weight_bytes: float   # parameter traffic (batch-independent)
    act_bytes: float      # activation traffic (scales with batch)


def _attn_flops(cfg: ModelConfig, b: int, s: int, ctx: int,
                window: Optional[int]) -> float:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = 2 * b * s * (d * m.q_lora_rank + m.q_lora_rank * h * qk
                            + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                            + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                            + h * m.v_head_dim * d)
        eff_ctx = min(ctx, window) if window else ctx
        att = 2 * b * s * h * eff_ctx * (qk + m.v_head_dim)
        return proj + att
    proj = 2 * b * s * d * (h * hd + 2 * kv * hd + h * hd)
    eff_ctx = min(ctx, window) if window else ctx
    att = 2 * b * s * h * eff_ctx * 2 * hd
    return proj + att


def _attn_weight_bytes(cfg: ModelConfig, dtype_bytes: int) -> float:
    return cfg._attn_params() * dtype_bytes


def _mlp_flops(cfg: ModelConfig, b: int, s: int) -> float:
    return 2 * b * s * 3 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ModelConfig, b: int, s: int) -> float:
    m = cfg.moe
    router = 2 * b * s * cfg.d_model * m.num_experts
    # capacity-bounded expert compute (sort-based dispatch, DESIGN.md §3)
    active = 2 * b * s * m.experts_per_token * 3 * cfg.d_model * cfg.d_ff
    return router + active * m.capacity_factor


def _ssm_flops(cfg: ModelConfig, b: int, s: int) -> float:
    sm = cfg.ssm
    d = cfg.d_model
    di = sm.d_inner(d)
    nh = sm.n_heads(d)
    N = sm.d_state
    proj = 2 * b * s * d * (2 * di + 2 * N + nh) + 2 * b * s * di * d
    # SSD: intra-chunk quadratic + state updates
    cs = min(sm.chunk_size, s)
    intra = 2 * b * s * cs * (N + di)        # scores + weighted sum
    states = 2 * b * s * di * N * 2          # state accumulate + output
    return proj + intra + states


def _rec_flops(cfg: ModelConfig, b: int, s: int) -> float:
    h = cfg.hybrid
    d = cfg.d_model
    w = h.lru_width or d
    proj = 2 * b * s * d * w * 2 + 2 * b * s * w * d
    gates = 2 * b * s * w * w * 2 / 16       # block-diagonal (16 blocks)
    scan = 6 * b * s * w
    return proj + gates + scan


def block_cost(cfg: ModelConfig, kind: str, batch: int, seq_q: int, ctx: int,
               *, window: Optional[int] = None, dtype_bytes: int = 2,
               name: str = "") -> NodeCost:
    """Cost of one layer over ``seq_q`` new tokens with ``ctx`` total context."""
    b, s = batch, seq_q
    d = cfg.d_model
    act_io = 2 * b * s * d * dtype_bytes     # read + write the residual stream

    if kind == "ssm":
        fl = _ssm_flops(cfg, b, s)
        wb = cfg._ssm_params() * dtype_bytes
        sm = cfg.ssm
        state_bytes = b * sm.n_heads(d) * sm.head_dim * sm.d_state * 4
        return NodeCost(name or "ssm", fl, wb, act_io + 2 * state_bytes)
    if kind == "rec":
        fl = _rec_flops(cfg, b, s) + _mlp_flops(cfg, b, s)
        h = cfg.hybrid
        w = h.lru_width or d
        wb = (2 * d * w + 2 * w * w / 16 + w * d + 3 * d * cfg.d_ff) * dtype_bytes
        state_bytes = b * w * 4
        return NodeCost(name or "rec", fl, wb, act_io + 2 * state_bytes)
    if kind == "moe":
        fl = (_attn_flops(cfg, b, s, ctx, window) + _moe_flops(cfg, b, s))
        m = cfg.moe
        active_ffn = 3 * d * cfg.d_ff * min(
            m.num_experts, m.experts_per_token * max(1, b * s))
        wb = (_attn_weight_bytes(cfg, dtype_bytes)
              + active_ffn * dtype_bytes + d * m.num_experts * 4)
        kv_bytes = b * ctx * 2 * cfg.kv_dim * dtype_bytes
        return NodeCost(name or "moe", fl, wb, act_io + kv_bytes)
    if kind == "mla":
        fl = _attn_flops(cfg, b, s, ctx, window) + _mlp_flops(cfg, b, s)
        wb = (_attn_weight_bytes(cfg, dtype_bytes) + 3 * d * cfg.d_ff * dtype_bytes)
        m = cfg.mla
        eff = min(ctx, window) if window else ctx
        kv_bytes = b * eff * (m.kv_lora_rank + m.qk_rope_head_dim) * dtype_bytes
        return NodeCost(name or "mla", fl, wb, act_io + kv_bytes)
    # dense
    fl = _attn_flops(cfg, b, s, ctx, window) + _mlp_flops(cfg, b, s)
    wb = (_attn_weight_bytes(cfg, dtype_bytes) + 3 * d * cfg.d_ff * dtype_bytes)
    eff = min(ctx, window) if window else ctx
    kv_bytes = b * eff * 2 * cfg.kv_dim * dtype_bytes
    return NodeCost(name or "dense", fl, wb, act_io + kv_bytes)


def _layer_kinds(cfg: ModelConfig) -> List[str]:
    if cfg.hybrid is not None:
        pat = cfg.hybrid.block_pattern
        return [pat[i % len(pat)] for i in range(cfg.num_layers)]
    if cfg.family == "ssm":
        return ["ssm"] * cfg.num_layers
    if cfg.moe is not None:
        return ["moe"] * cfg.num_layers
    if cfg.attention == "mla":
        return ["mla"] * cfg.num_layers
    return ["dense"] * cfg.num_layers


def _layer_window(cfg: ModelConfig, kind: str, flags_window) -> Optional[int]:
    if cfg.hybrid is not None and kind == "attn":
        return cfg.hybrid.local_window
    return flags_window


def step_costs(cfg: ModelConfig, phase: str, batch: int, seq_or_ctx: int,
               *, window: Optional[int] = None,
               dtype_bytes: int = 2) -> List[NodeCost]:
    """Full node sequence for one phase.

    phase: "prefill"/"train" — seq_or_ctx is the sequence length;
           "decode" — seq_or_ctx is the context length (one new token).
    """
    d = cfg.d_model
    nodes = []
    if phase == "decode":
        s, ctx = 1, seq_or_ctx
    else:
        s, ctx = seq_or_ctx, seq_or_ctx
    nodes.append(NodeCost("embed", 0.0, min(batch * s, cfg.vocab_size) * d * dtype_bytes,
                          batch * s * d * dtype_bytes))
    for i, kind in enumerate(_layer_kinds(cfg)):
        k = "dense" if kind == "attn" else kind
        win = cfg.hybrid.local_window if (cfg.hybrid and kind == "attn") else window
        c = block_cost(cfg, k, batch, s, ctx, window=win,
                       dtype_bytes=dtype_bytes, name=f"L{i}:{kind}")
        nodes.append(c)
    head_s = 1 if phase != "train" else s
    nodes.append(NodeCost(
        "head",
        2 * batch * head_s * d * cfg.vocab_size,
        d * cfg.vocab_size * dtype_bytes,
        batch * head_s * (d + cfg.vocab_size) * dtype_bytes))
    return nodes


def model_flops(cfg: ModelConfig, tokens: int, train: bool = False) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); 2·N·D for inference."""
    n = cfg.active_param_count()
    per_tok = 6 * n if train else 2 * n
    return per_tok * tokens
