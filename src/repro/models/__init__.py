from .model import Model, RuntimeFlags
from .cost import NodeCost, block_cost, step_costs, model_flops

__all__ = ["Model", "RuntimeFlags", "NodeCost", "block_cost", "step_costs",
           "model_flops"]
