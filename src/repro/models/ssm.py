"""Mamba-2 (SSD — state-space duality) mixer.

Chunked SSD algorithm (arXiv:2405.21060 §6): within-chunk quadratic
("attention-like") term + across-chunk linear recurrence. The across-chunk
recurrence is a first-order linear scan computed with
``jax.lax.associative_scan`` — log-depth, fully unrolled in HLO so
(a) cost analysis counts it exactly and (b) no sequential while-loop on the
TPU critical path (hardware adaptation: the original CUDA kernel uses a
sequential inter-chunk pass; on TPU the log-depth scan maps to large
batched matmuls).

Projections are stored as separate parameters (w_z / w_x / w_bc / w_dt)
rather than one fused in_proj so each can carry its own partition spec
without split boundaries crossing shards; XLA fuses the matmuls anyway.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..sharding import shard
from .layers import init_rmsnorm, rms_norm


def init_ssm(key, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    scale = 1.0 / math.sqrt(d)
    dt = jnp.exp(jax.random.uniform(ks[5], (nh,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "w_z": (jax.random.normal(ks[0], (d, di)) * scale).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d, di)) * scale).astype(dtype),
        "w_bc": (jax.random.normal(ks[2], (d, gn)) * scale).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (d, nh)) * scale).astype(dtype),
        "conv_x": (jax.random.normal(ks[4], (s.conv_width, di)) * 0.2).astype(dtype),
        "conv_bc": (jax.random.normal(ks[6], (s.conv_width, gn)) * 0.2).astype(dtype),
        "conv_bias_x": jnp.zeros((di,), dtype),
        "conv_bias_bc": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),          # inverse softplus
        "norm": init_rmsnorm(di),
        "out_proj": (jax.random.normal(ks[7], (di, d)) * (1 / math.sqrt(di))).astype(dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along the sequence axis.

    x: (B, S, C); w: (W, C). Implemented as a sum of shifted copies
    (width <= 4), which XLA fuses — no conv primitive needed.
    """
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[W - 1 - i]
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, B_ssm, C_ssm, chunk: int):
    """SSD forward over a full sequence.

    x: (B, S, nh, hd); dt: (B, S, nh) (post-softplus);
    A: (nh,) negative reals; B_ssm, C_ssm: (B, S, N) (n_groups == 1).
    Returns y: (B, S, nh, hd) and the final state (B, nh, hd, N).
    """
    Bb, S, nh, hd = x.shape
    N = B_ssm.shape[-1]
    nc = S // chunk
    xc = x.reshape(Bb, nc, chunk, nh, hd)
    dtc = dt.reshape(Bb, nc, chunk, nh)
    Bc = B_ssm.reshape(Bb, nc, chunk, N)
    Cc = C_ssm.reshape(Bb, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                     # (B,nc,cs,nh), <= 0
    cum = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum
    total = cum[:, :, -1]                                 # (B,nc,nh)

    # ---- intra-chunk (quadratic) term: L[i,j] = exp(cum_i - cum_j), j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (B,nc,i,j)
    w = scores[..., None] * L * dtc[:, :, None, :, :]     # weight on x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xc)

    # ---- chunk summary states: S_c = sum_j exp(total - cum_j) dt_j x_j B_j^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)    # (B,nc,cs,nh)
    xw = xc * (decay_to_end * dtc)[..., None]
    states = jnp.einsum("bcjhp,bcjn->bchpn", xw, Bc.astype(x.dtype))

    # ---- inter-chunk linear recurrence via associative scan
    decay = jnp.exp(total)                                # (B,nc,nh)

    def combine(a, b):
        da, sa = a
        db, sb = b
        return da * db, sa * db[..., None, None] + sb

    dec_s, st_s = jax.lax.associative_scan(
        combine, (decay.astype(jnp.float32), states.astype(jnp.float32)), axis=1)
    # state *entering* chunk c = scanned state of chunk c-1
    h_prev = jnp.pad(st_s[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    # decay from chunk start to position i: exp(cum_i)
    Ci = Cc[:, :, :, None, :] * jnp.exp(cum)[..., None]   # (B,nc,cs,nh,N)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Ci.astype(jnp.float32),
                         h_prev).astype(x.dtype)

    y = (y_intra + y_inter).reshape(Bb, S, nh, hd)
    final_state = st_s[:, -1]                             # (B,nh,hd,N)
    return y, final_state


def apply_ssm_dense(p: dict, x_in: jax.Array, cfg, *, chunk: Optional[int] = None):
    """Full-sequence Mamba-2 mixer. x_in: (B, S, d) -> (y, cache)."""
    s = cfg.ssm
    B, S, d = x_in.shape
    chunk = chunk or s.chunk_size
    while S % chunk:
        chunk //= 2
    di = s.d_inner(d)
    nh = s.n_heads(d)
    N = s.d_state

    z = x_in @ p["w_z"]
    x_raw = x_in @ p["w_x"]
    bc_raw = x_in @ p["w_bc"]
    dt = x_in @ p["w_dt"]
    xs = _causal_conv(x_raw, p["conv_x"], p["conv_bias_x"])
    bc = _causal_conv(bc_raw, p["conv_bc"], p["conv_bias_bc"])
    xs = shard(xs.reshape(B, S, nh, s.head_dim), "batch", "seq", "ssm_heads", None)
    Bs, Cs = jnp.split(bc, 2, axis=-1)                    # (B,S,N) each (g==1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dtv = shard(dtv, "batch", "seq", "ssm_heads")
    A = -jnp.exp(p["A_log"])

    y, final_state = ssd_chunked(xs, dtv, A, Bs, Cs, chunk)
    y = y + xs * p["D"][None, None, :, None].astype(x_in.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]

    W = s.conv_width
    conv_cache = jnp.concatenate([x_raw, bc_raw], axis=-1)[:, -(W - 1):, :]
    cache = {"state": final_state.astype(jnp.float32), "conv": conv_cache}
    return shard(out, "batch", "act_seq", "embed"), cache


def apply_ssm_decode(p: dict, x_in: jax.Array, cache: dict, cfg):
    """Single-token recurrent update. x_in: (B, d)."""
    s = cfg.ssm
    B, d = x_in.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    N = s.d_state

    z = x_in @ p["w_z"]
    x_raw = x_in @ p["w_x"]
    bc_raw = x_in @ p["w_bc"]
    dt = x_in @ p["w_dt"]
    new_tail = jnp.concatenate([x_raw, bc_raw], axis=-1)   # (B, di+gn)
    conv_in = jnp.concatenate([cache["conv"], new_tail[:, None]], axis=1)
    xs_in, bc_in = jnp.split(conv_in, [di], axis=-1)
    xs = jax.nn.silu(jnp.einsum("bwc,wc->bc", xs_in, p["conv_x"]) + p["conv_bias_x"])
    bc = jax.nn.silu(jnp.einsum("bwc,wc->bc", bc_in, p["conv_bc"]) + p["conv_bias_bc"])
    xs = xs.reshape(B, nh, s.head_dim)
    Bs, Cs = jnp.split(bc, 2, axis=-1)                     # (B,N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    A = -jnp.exp(p["A_log"])

    decay = jnp.exp(dtv * A)                               # (B,nh)
    h = cache["state"]                                     # (B,nh,hd,N) f32
    contrib = (dtv[..., None, None] * xs.astype(jnp.float32)[..., None]
               * Bs.astype(jnp.float32)[:, None, None, :])
    h = h * decay[..., None, None] + contrib
    y = jnp.einsum("bhpn,bn->bhp", h, Cs.astype(jnp.float32)).astype(x_in.dtype)
    y = y + xs * p["D"][None, :, None].astype(x_in.dtype)
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_conv = conv_in[:, 1:]
    return out, {"state": h, "conv": new_conv}


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return {
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }
