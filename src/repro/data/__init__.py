"""Data pipeline: synthetic token streams + WMT-like length sampling.

Offline container — the pipeline synthesizes token sequences whose summary
statistics match the serving-side length characterization (Fig. 11), so the
profile-driven ``dec_timesteps`` mechanism is exercised end-to-end by the
training example and the benchmarks.
"""
from .pipeline import DataConfig, TokenPipeline, make_batch_specs

__all__ = ["DataConfig", "TokenPipeline", "make_batch_specs"]
