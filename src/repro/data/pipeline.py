"""Synthetic token pipeline.

Deterministic, seedable, host-side (numpy) generation with double-buffered
prefetch semantics: ``__iter__`` yields ready batches while the next one is
synthesized. Sequences are drawn from a Zipfian unigram model with
document boundaries sampled from the WMT-like length distribution, so the
pipeline also doubles as the output-length characterization source used by
the slack predictor's ``dec_timesteps`` quantile (paper Fig. 11).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, InputShape


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    doc_len_mean: float = 180.0  # mean document length (tokens)
    eos_id: int = 1
    pad_id: int = 0


class TokenPipeline:
    """Infinite iterator of {"tokens", "targets"} numpy batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # Zipfian unigram distribution over the vocab (precomputed CDF).
        ranks = np.arange(2, cfg.vocab_size, dtype=np.float64)  # skip pad/eos
        w = 1.0 / ranks ** cfg.zipf_a
        self._cdf = np.cumsum(w) / w.sum()

    def _sample_tokens(self, n: int) -> np.ndarray:
        u = self.rng.random(n)
        return (np.searchsorted(self._cdf, u) + 2).astype(np.int32)

    def _sample_stream(self, n: int) -> np.ndarray:
        """Token stream with EOS-delimited documents."""
        out = np.empty(n + 1, np.int32)
        i = 0
        while i <= n:
            dl = max(1, int(self.rng.exponential(self.cfg.doc_len_mean)))
            dl = min(dl, n + 1 - i)
            out[i:i + dl] = self._sample_tokens(dl)
            i += dl
            if i <= n:
                out[i] = self.cfg.eos_id
                i += 1
        return out[:n + 1]

    def next_batch(self) -> dict:
        c = self.cfg
        toks = np.stack([self._sample_stream(c.seq_len) for _ in range(c.batch_size)])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def output_length_samples(self, n: int = 10_000) -> np.ndarray:
        """Document lengths — the characterization feed for dec_timesteps."""
        return np.maximum(
            1, self.rng.exponential(self.cfg.doc_len_mean, size=n).astype(int))


def make_batch_specs(cfg: ModelConfig, shape: InputShape,
                     dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for one phase's inputs (dry-run pattern:
    weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.modality is not None and cfg.num_prefix_embeddings:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.modality is not None and cfg.num_prefix_embeddings:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, cfg.d_model), dtype)
        return specs
    # decode: ONE new token per row, ragged positions within [0, S)
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
    }
