"""Granite-3.0 MoE 3B-a800m — fine-grained MoE, 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] family; assigned dims:
32L, d_model=1536, 24 heads (GQA kv=8), per-expert d_ff=512,
vocab=49155, MoE 40 experts top-8.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    attention="gqa",
    rope_theta=1e4,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, experts_per_token=8, capacity_factor=1.25),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (family card)",
)
