"""Llama-3.2-1B — small dense llama3.

[hf:meta-llama/Llama-3.2-1B] 16L, d_model=2048, 32 heads (GQA kv=8),
d_ff=8192, vocab=128256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    attention="gqa",
    rope_theta=5e5,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)
