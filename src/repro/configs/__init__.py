"""Architecture registry.

Each assigned architecture lives in its own module and registers exactly the
configuration from the assignment brief (source citation included).
"""
from __future__ import annotations

from .base import (InputShape, INPUT_SHAPES, MLAConfig, MoEConfig, ModelConfig,
                   SSMConfig, HybridConfig)

from . import (qwen2_5_32b, musicgen_large, granite_moe_3b_a800m,
               internvl2_26b, llama3_2_1b, grok_1_314b, recurrentgemma_9b,
               mistral_nemo_12b, minicpm3_4b, mamba2_2_7b)

ARCHITECTURES: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen2_5_32b.CONFIG,
        musicgen_large.CONFIG,
        granite_moe_3b_a800m.CONFIG,
        internvl2_26b.CONFIG,
        llama3_2_1b.CONFIG,
        grok_1_314b.CONFIG,
        recurrentgemma_9b.CONFIG,
        mistral_nemo_12b.CONFIG,
        minicpm3_4b.CONFIG,
        mamba2_2_7b.CONFIG,
    ]
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(ARCHITECTURES)}")


def get_shape(name: str) -> InputShape:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; available: {sorted(INPUT_SHAPES)}")


__all__ = [
    "ARCHITECTURES", "INPUT_SHAPES", "ModelConfig", "InputShape", "MoEConfig",
    "MLAConfig", "SSMConfig", "HybridConfig", "get_config", "get_shape",
]
