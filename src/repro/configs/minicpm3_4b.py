"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B] 62L, d_model=2560, 40 heads (kv=40 logical; MLA
caches a compressed latent), d_ff=6400, vocab=73448.
MLA dims per the model card: q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v=64.
"""
from .base import ModelConfig, MLAConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    rope_theta=1e4,
    tie_embeddings=True,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B",
)
