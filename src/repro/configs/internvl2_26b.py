"""InternVL2-26B — InternViT-6B vision encoder + InternLM2-20B LLM.

[arXiv:2404.16821] Assigned backbone dims (the LLM we implement):
48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92553.
The InternViT encoder + MLP projector are a stub: ``input_specs``
provides precomputed patch embeddings of width d_model.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attention="gqa",
    rope_theta=1e6,
    modality="vision",
    num_prefix_embeddings=1024,   # ViT patch tokens after pixel-shuffle
    source="arXiv:2404.16821 (InternVL2)",
)
