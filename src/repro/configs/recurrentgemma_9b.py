"""RecurrentGemma-9B — Griffin-style hybrid: RG-LRU + local attention (1:2).

[arXiv:2402.19427] 38L, d_model=4096, 16 heads (MQA kv=1, head_dim=256),
d_ff=12288, vocab=256000; block pattern (rec, rec, attn), local window 2048.
"""
from .base import ModelConfig, HybridConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,   # 12 x (rec, rec, attn) + 2 trailing rec
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention="gqa",
    rope_theta=1e4,
    tie_embeddings=True,
    hybrid=HybridConfig(block_pattern=("rec", "rec", "attn"),
                        lru_width=4096, local_window=2048, conv_width=4),
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)
