"""Mamba2-2.7B — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] 64L, d_model=2560 (d_inner=5120, 80 heads of 64),
ssm_state=128, vocab=50280.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256,
                  conv_width=4, n_groups=1),
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)
