"""Model / input-shape configuration dataclasses.

Every assigned architecture is expressed as a single ``ModelConfig``; the
model builder in ``repro.models.model`` consumes it to construct parameter
pytrees, train/prefill/decode step functions, and the node-level graph used
by the LazyBatching scheduler.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD configuration."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    conv_width: int = 4
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma / Griffin style block pattern."""
    # Pattern applied cyclically, e.g. ("rec", "rec", "attn").
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: int = 0           # 0 -> d_model
    local_window: int = 2048
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""             # citation from the assignment brief

    attention: str = "gqa"       # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Long-context variant: when serving ``long_500k`` on attention archs we
    # switch to a ring-buffer sliding window of this many tokens (DESIGN.md §5).
    long_context_window: int = 8192

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # Modality stubs: [audio]/[vlm] archs receive ``num_prefix_embeddings``
    # precomputed frame/patch embeddings of width d_model from the frontend
    # stub in train/prefill shapes (the brief's one allowed carve-out).
    modality: Optional[str] = None       # "vision" | "audio"
    num_prefix_embeddings: int = 0

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.attention == "none" and self.ssm is None:
            raise ValueError("attention='none' requires an SSMConfig — "
                             "an attention-free arch must be SSM")

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # unembed
        n += self.num_layers * self._block_params() + d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full_ffn = 3 * d * self.d_ff * self.moe.num_experts
        act_ffn = 3 * d * self.d_ff * self.moe.experts_per_token
        return self.param_count() - self.num_layers * (full_ffn - act_ffn)

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_dim
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.num_heads * m.v_head_dim * d
            return n
        n = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            n += self.q_dim + 2 * self.kv_dim
        return n

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            router = d * self.moe.num_experts
            return router + 3 * d * self.d_ff * self.moe.num_experts
        return 3 * d * self.d_ff      # SwiGLU: gate, up, down

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        di = s.d_inner(d)
        nh = s.n_heads(d)
        conv_dim = di + 2 * s.n_groups * s.d_state
        n = d * (2 * di + 2 * s.n_groups * s.d_state + nh)   # in_proj (z,x,B,C,dt)
        n += conv_dim * s.conv_width                          # conv1d
        n += nh * 2                                           # A_log, D
        n += di * d                                           # out_proj
        return n

    def _block_params(self) -> int:
        d = self.d_model
        if self.family == "ssm":
            return self._ssm_params() + d
        if self.hybrid is not None:
            h = self.hybrid
            pat = h.block_pattern
            lru_w = h.lru_width or d
            # recurrent block: in projections, conv, RG-LRU gates, out proj
            rec = d * lru_w * 2 + lru_w * h.conv_width + 3 * lru_w * lru_w + lru_w * d
            attn = self._attn_params()
            per = {"rec": rec + 2 * d, "attn": attn + 2 * d}
            total = sum(per[b] for b in pat) + len(pat) * self._ffn_params()
            return total // len(pat)   # average per layer
        return self._attn_params() + self._ffn_params() + 2 * d

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = min(self.head_dim, 64)
        nh = max(2, min(self.num_heads, 4))
        nkv = max(1, min(self.num_kv_heads, nh))
        if nh % nkv:
            nkv = 1
        kw = dict(
            num_layers=2 if self.hybrid is None else len(self.hybrid.block_pattern),
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            num_prefix_embeddings=min(self.num_prefix_embeddings, 8),
            long_context_window=256,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4,
                experts_per_token=min(self.moe.experts_per_token, 2))
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32,
                                            chunk_size=32)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(self.hybrid, lru_width=0,
                                               local_window=64)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
