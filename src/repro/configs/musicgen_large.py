"""MusicGen-large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] 48L, d_model=2048, 32 heads (kv=32, i.e. MHA),
d_ff=8192, vocab=2048 (EnCodec codebook size). The EnCodec/conditioning
frontend is a stub: ``input_specs`` provides precomputed frame embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    attention="gqa",
    rope_theta=1e4,
    modality="audio",
    num_prefix_embeddings=256,   # conditioning frames from the codec stub
    source="arXiv:2306.05284 (MusicGen)",
)
