"""Grok-1 314B — large MoE, 8 experts top-2.

[hf:xai-org/grok-1] 64L, d_model=6144, 48 heads (GQA kv=8),
per-expert d_ff=32768, vocab=131072, MoE 8e top-2.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    attention="gqa",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=8, experts_per_token=2, capacity_factor=1.25),
    source="hf:xai-org/grok-1",
)
