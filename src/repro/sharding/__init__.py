"""Logical-axis sharding helpers.

Model code annotates activations with *logical* axis names
("batch", "seq", "heads", "embed", "ffn", "vocab", "experts", ...).
The launcher installs a mapping logical-axis -> mesh-axis; outside a mesh
context the annotations are no-ops, so the same model code runs on a single
CPU device (tests) and on the production mesh (dry-run).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class AxisRules:
    """Maps logical axis names to mesh axis names (or None = replicated)."""

    def __init__(self, mesh: Mesh, mapping: Mapping[str, object]):
        self.mesh = mesh
        self.mapping = dict(mapping)

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        out = []
        for ax in logical:
            m = self.mapping.get(ax) if ax is not None else None
            out.append(m)
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def shard(x, *logical: Optional[str]):
    """Apply a sharding constraint if logical rules are installed."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank mismatch: {x.shape} vs logical axes {logical}")
    return jax.lax.with_sharding_constraint(x, rules.sharding(logical))


# Default logical->mesh mappings -----------------------------------------

# Tensor-parallel serving: params replicated over `data`, sharded over
# `model`; batch over (`pod`, `data`).
SERVE_RULES = {
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": None,
    "expert_ffn": "model",
    "moe_out": None,       # §Perf hook: -> "model" defers the TP all-reduce
    "act_seq": None,       # §Perf hook: -> "model" = Megatron-style seq parallel
    "lru": "model",
    "ssm_heads": "model",
    "state": None,
    "layers": None,
    "fsdp": None,
}

# Training: same tensor parallelism + params FSDP-sharded over `data`.
TRAIN_RULES = dict(SERVE_RULES, fsdp="data")


def make_rules(mesh: Mesh, kind: str = "serve") -> AxisRules:
    base = TRAIN_RULES if kind == "train" else SERVE_RULES
    mapping = dict(base)
    names = mesh.axis_names
    if "pod" not in names:
        mapping["batch"] = "data"
    if "data" not in names:
        mapping["batch"] = None
        mapping["batch_nopod"] = None
        mapping["fsdp"] = None
    if "model" not in names:
        for k, v in list(mapping.items()):
            if v == "model":
                mapping[k] = None
    return AxisRules(mesh, mapping)
