"""The request/handle lifecycle state machine, as ONE declarative table.

PR 7 documented the handle lifecycle as *monotone-except-retry*: a
request only ever moves forward through

    QUEUED -> ADMITTED -> RUNNING -> DONE

or jumps from a live state to a terminal disposition (``REJECTED`` at
admission, ``CANCELLED``/``EXPIRED``/``FAILED``/``SHED`` out-of-band),
with exactly ONE backward edge — the fault-retry rewind
``RUNNING -> QUEUED`` — and every terminal state absorbing. That
contract used to live in three places at once (the ``HandleState`` enum,
the ``_FATE_STATE`` dict, and prose in docstrings), which is how a
drifting edge stays unnoticed until a property test trips over it.

This module is now the single source of truth. The runtime imports it
(:mod:`repro.serving.session` derives its enum and terminal sets from
``STATES``/``TERMINAL``/``FATES``; :class:`repro.core.request.Request`
validates ``fate`` writes against ``FATES``) and the ``handle-lattice``
static checker (:mod:`repro.analysis.handles`) imports it too — so the
code that moves handles and the analysis that polices those moves can
never disagree about what a legal edge is.

``_validate()`` runs at import and raises if the table itself stops
being monotone-except-retry (a backward edge sneaking in, an edge out of
a terminal state), so an illegal edit to the TABLE is as loud as an
illegal edit to the code.
"""
from __future__ import annotations

from typing import FrozenSet, Tuple

#: Every lifecycle state, in lattice order: the live progression first,
#: then the terminal dispositions. (serving.session derives its
#: ``HandleState`` enum from this tuple — order is part of the contract.)
STATES: Tuple[str, ...] = (
    "queued", "admitted", "running",
    "done", "rejected", "cancelled", "expired", "failed", "shed",
)

#: Live (non-terminal) states, in progression order.
LIVE: Tuple[str, ...] = ("queued", "admitted", "running")

#: Terminal states — absorbing: no legal edge leaves one.
TERMINAL: FrozenSet[str] = frozenset(STATES) - frozenset(LIVE)

#: Out-of-band terminal dispositions recorded on ``Request.fate``
#: (``done``/``rejected`` are reached through the normal bookkeeping —
#: ``t_finish`` / rejection at submit — never through ``fate``).
FATES: Tuple[str, ...] = ("cancelled", "expired", "failed", "shed")

#: The ONE backward edge: a faulted dispatch rewinds its members from
#: RUNNING back to QUEUED for a backoff-delayed prefill replay.
RETRY_EDGE: Tuple[str, str] = ("running", "queued")

#: The full legal edge set. Everything except RETRY_EDGE moves strictly
#: forward in ``STATES`` order; terminal states have no out-edges.
EDGES: FrozenSet[Tuple[str, str]] = frozenset(
    {
        ("queued", "admitted"),      # policy pulls it out of the InfQ
        ("admitted", "running"),     # first committed run executes
        ("running", "done"),         # final node at a run boundary
        ("queued", "rejected"),      # admission control at submit
        RETRY_EDGE,                  # fault retry (the one rewind)
    }
    # any live state can go terminal out-of-band: cancel works on queued
    # and batched work alike, expiry sweeps queued+admitted+running,
    # shedding hits the ingress and the backlog, faults hit dispatched
    # (running) members whose retry budget is gone
    | {(live, fate) for live in LIVE for fate in FATES}
)

# ---------------------------------------------------------------------------
# Declarations consumed by the handle-lattice static checker: which
# attribute writes encode a BACKWARD move (the retry rewind), and which
# functions are licensed to perform them / to write fates dynamically.
# ---------------------------------------------------------------------------

#: Attribute writes that rewind a handle/request along the lattice —
#: each maps the attribute to the literal rewind value. Writing one of
#: these outside ``RETRY_FUNCTIONS`` (or an ``__init__``) is an illegal
#: backward edge: the derived state would jump RUNNING/ADMITTED -> QUEUED
#: with no fault to justify it.
ROLLBACK_WRITES = {
    "t_first_issue": None,   # un-admits: derived state falls to QUEUED
    "idx": 0,                # prefill replay from node 0
    "_running": False,       # clears the RUNNING observation
}

#: The only functions allowed to take the RETRY_EDGE (and therefore to
#: perform ROLLBACK_WRITES): the session's fault handler.
RETRY_FUNCTIONS: FrozenSet[str] = frozenset({"_on_fault"})

#: The only functions allowed to assign a NON-LITERAL fate (the single
#: validated funnel every terminal disposition routes through); literal
#: fate writes are checked against ``FATES`` wherever they appear.
FATE_SETTER_FUNCTIONS: FrozenSet[str] = frozenset({"_terminate"})


def is_terminal(state: str) -> bool:
    return state in TERMINAL


def legal(src: str, dst: str) -> bool:
    """True iff ``src -> dst`` is a legal lifecycle edge."""
    return (src, dst) in EDGES


def _validate() -> None:
    rank = {s: i for i, s in enumerate(STATES)}
    unknown = {s for e in EDGES for s in e} - set(STATES)
    if unknown:
        raise RuntimeError(f"lifecycle EDGES mention unknown states "
                           f"{sorted(unknown)} (STATES={STATES})")
    for src, dst in EDGES:
        if src in TERMINAL:
            raise RuntimeError(
                f"lifecycle edge {src!r} -> {dst!r} leaves a terminal "
                f"state — terminal states are absorbing")
        if (src, dst) != RETRY_EDGE and rank[src] >= rank[dst]:
            raise RuntimeError(
                f"lifecycle edge {src!r} -> {dst!r} moves backward — the "
                f"machine is monotone except the retry edge {RETRY_EDGE}")
    if RETRY_EDGE not in EDGES:
        raise RuntimeError("lifecycle RETRY_EDGE missing from EDGES")
    if not set(FATES) <= TERMINAL:
        raise RuntimeError(f"every fate must be terminal: {FATES}")


_validate()
