"""Cross-model SLA arbitration for co-located serving (paper §VI-C).

Batching happens *within* a model — batch tables are per-graph, and
sub-batches of different models never merge — but the accelerator is one:
when several registered models have a committed run ready, something must
decide whose run dispatches next. That decision is the *arbiter*, the one
scheduling layer that sits above the per-model policies:

  * :class:`RoundRobinArbiter` — the GraphBatching-style baseline: cycle
    through the registered models in registration order, skipping models
    with nothing ready. SLA-blind, starvation-free.
  * :class:`LeastSlackArbiter` — the LazyBatching-style SLA-aware arbiter:
    dispatch the model whose most urgent live request has the least
    predicted slack (its policy's conservative slack predictor, Eq. 2);
    models whose policy carries no predictor are ranked by earliest
    absolute deadline (``arrival + per-request/default SLA``), the EDF
    degeneration. Ties break on earliest arrival (FIFO across models),
    then registration order — no model can starve: a parked model's slack
    and absolute deadline both decay monotonically while it waits, so it
    eventually ranks first.

An arbiter sees *candidates*: ``(entry, sub_batch, run)`` triples, one per
registered model whose policy returned work this scheduling step, where
``entry`` is the session's :class:`~repro.serving.registry.ModelEntry`
(exposing ``name``, ``policy``, and registration ``index``). ``pick``
returns the index of the candidate to dispatch. With a single registered
model the session never consults the arbiter, so single-model serving is
bit-identical to the pre-registry sessions regardless of arbiter choice.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

_INF = float("inf")

# (entry, sub_batch, committed run) — entry is a registry ModelEntry
Candidate = Tuple[object, object, Tuple[str, ...]]


class Arbiter:
    """Picks which model's committed run dispatches next.

    ``mem_shares``: optional per-model **device-memory shares** for
    bounded-memory serving — ``{"gold": 0.5, "bulk": 0.5}`` caps each
    model's admitted-resident KV slots at its fraction of the pool's
    ``max_slots``, so one bulk tenant can never starve an interactive
    tenant of slots. The session's memory-aware admission consults
    :meth:`mem_share` (an explicit ``register(mem_share=...)`` on the
    model entry takes precedence); models without a share draw freely
    from the unreserved pool. Ignored when the backend reports no memory
    cap.
    """

    name = "abstract"

    def __init__(self, mem_shares: Optional[Dict[str, float]] = None):
        # real errors, not asserts: a silently-constructed oversubscribed
        # share map under ``python -O`` would quietly void the
        # anti-starvation guarantee
        if mem_shares is not None:
            if not all(0.0 < s <= 1.0 for s in mem_shares.values()):
                raise ValueError(
                    f"memory shares must lie in (0, 1]: {mem_shares}")
            if sum(mem_shares.values()) > 1.0 + 1e-9:
                raise ValueError(
                    f"memory shares oversubscribe the pool: {mem_shares}")
        self.mem_shares = dict(mem_shares) if mem_shares else None

    def mem_share(self, model: str) -> Optional[float]:
        """The fraction of the memory pool reserved-as-cap for ``model``
        (None = uncapped: the model draws from the shared pool)."""
        return None if self.mem_shares is None else self.mem_shares.get(model)

    def pick(self, candidates: List[Candidate], now: float) -> int:
        raise NotImplementedError


class RoundRobinArbiter(Arbiter):
    """Baseline: rotate through registered models in registration order,
    skipping models with no ready work (the per-model GraphBatching
    deployment the paper compares against: fair device shares, no SLA
    awareness)."""

    name = "rr"

    def __init__(self, mem_shares: Optional[Dict[str, float]] = None):
        super().__init__(mem_shares=mem_shares)
        self._last = -1          # registration index of the last dispatch

    def pick(self, candidates, now):
        # exact cyclic order without a modulus: candidates past the last
        # dispatched index come first (ascending), wrapped ones after
        best = min(range(len(candidates)),
                   key=lambda i: (candidates[i][0].index <= self._last,
                                  candidates[i][0].index))
        self._last = candidates[best][0].index
        return best


class LeastSlackArbiter(Arbiter):
    """SLA-aware arbitration: least predicted slack across models.

    A candidate's urgency is the minimum over its sub-batch's live
    requests of the model policy's conservative slack estimate
    (``predictor.slack(r, [r], now)`` — Eq. 2 with the request alone, the
    same quantity LazyBatching's anti-starvation promotion uses). When the
    policy has no slack predictor the request's time-to-absolute-deadline
    (``arrival + deadline - now``) stands in — slack minus remaining
    execution time degenerates to EDF ordering. Requests with neither an
    SLA class nor a ``sla_default`` rank last (infinite slack).
    """

    name = "least-slack"

    def __init__(self, sla_default: Optional[float] = None,
                 mem_shares: Optional[Dict[str, float]] = None):
        super().__init__(mem_shares=mem_shares)
        self.sla_default = sla_default

    def _urgency(self, entry, sb, now: float):
        pred = getattr(entry.policy, "predictor", None)
        best_u = best_arr = _INF
        for r in sb.live_requests:
            if pred is not None:
                u = pred.slack(r, [r], now)
            else:
                d = r.sla.deadline if r.sla is not None else self.sla_default
                u = (r.arrival + d - now) if d is not None else _INF
            best_u = min(best_u, u)
            best_arr = min(best_arr, r.arrival)
        return best_u, best_arr

    def pick(self, candidates, now):
        keys = [self._urgency(e, sb, now) + (e.index,)
                for (e, sb, _run) in candidates]
        return min(range(len(candidates)), key=keys.__getitem__)


ARBITERS = {
    RoundRobinArbiter.name: RoundRobinArbiter,
    LeastSlackArbiter.name: LeastSlackArbiter,
}
