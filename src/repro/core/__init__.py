"""The paper's contribution: SLA-aware node-level batching (LazyBatching)."""
from .request import Request, SLAClass, SubBatch
from .batch_table import BatchTable
from .slack import SlackPredictor, OracleSlackPredictor
from .policies import (Policy, Serial, GraphBatching, CellularBatching,
                       LazyBatching, Oracle)
from .arbiter import (Arbiter, RoundRobinArbiter, LeastSlackArbiter,
                      ARBITERS)

__all__ = [
    "Request", "SLAClass", "SubBatch", "BatchTable", "SlackPredictor",
    "OracleSlackPredictor", "Policy", "Serial", "GraphBatching",
    "CellularBatching", "LazyBatching", "Oracle",
    "Arbiter", "RoundRobinArbiter", "LeastSlackArbiter", "ARBITERS",
]
