"""SLA-aware slack time prediction (paper §IV-C, Eq. 1-2, Algorithm 1).

    Slack_r = SLA_r - (T_wait_r + Σ_{i in batch} SingleInputExecTime_i)

Deliberately conservative: the latency of a batch is overestimated as the
*sum* of its members' isolated single-batch latencies, so estimated slack
shrinks and SLA violations are minimized first, throughput second.

``SLA_r`` is *per request*: a request carrying an :class:`~repro.core.
request.SLAClass` is judged against its own class deadline; requests
without one fall back to the predictor's global ``sla_target`` (the
paper's single frozen scalar), so single-tier behavior is unchanged while
mixed-tier traces get per-tier admission control.

SingleInputExecTime_i comes from the profiled per-node latency lookup table
(``NodeLatency(n)``); dynamic graphs are overprovisioned with
``dec_timesteps`` = the N-% quantile of the output-length distribution
(default N = 90%, paper Fig. 11).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from .request import Request

# memoized single-exec entries across ALL live requests before a panic
# clear (a backstop only: entries are evicted per request on completion)
_MEMO_CAP = 100_000


class _PredictorBase:
    """Shared predictor scaffolding: the per-request deadline rule and the
    per-rid memo — one dict of entries per live request, evicted wholesale
    via :meth:`forget` when the request finishes (wired through
    ``Policy.request_finished``), with a global-size panic clear as a leak
    backstop."""

    _memo_cap = _MEMO_CAP

    def deadline(self, req: Request) -> float:
        """The deadline ``req`` is judged against: its own SLA class when
        it carries one, else the predictor's global target."""
        return self.sla_target if req.sla is None else req.sla.deadline

    def _memo_get(self, rid: int) -> Dict:
        per = self._memo.get(rid)
        if per is None:
            if self._memo_n > self._memo_cap:     # leak backstop
                self._memo.clear()
                self._memo_n = 0
            per = self._memo[rid] = {}
        return per

    def forget(self, rid: int) -> None:
        """Drop all memoized entries of a finished request."""
        per = self._memo.pop(rid, None)
        if per is not None:
            self._memo_n -= len(per)

    def release_bound(self, ongoing: Iterable["Request"]) -> float:
        """Lower-bound-style estimate of how long until the earliest KV
        slot frees: the smallest remaining single-input execution time
        among the resident requests (0 when none are resident). Used by
        memory-aware admission control to decide whether a request whose
        model's memory pool is exhausted could still get a slot before
        its own deadline — the same Eq. 1 per-request quantities the
        slack bound is built from, so rejection stays exactly as
        conservative as the paper's admission."""
        times = [self.single_remaining(r) for r in ongoing]
        return min(times) if times else 0.0

    @property
    def memo_size(self) -> int:
        return sum(len(per) for per in self._memo.values())


@dataclass
class SlackPredictor(_PredictorBase):
    sla_target: float
    # per-workload-name profiled node latency tables (single-batch)
    tables: Dict[str, Dict[str, float]]
    # per-workload-name dec_timesteps (quantile of decode-length profile)
    dec_timesteps: Dict[str, int]
    coverage: float = 0.90
    # per-rid memo of single_remaining values: {rid: {idx: seconds}} —
    # evicted via forget(rid) when the request finishes
    _memo: Dict[int, Dict] = field(default_factory=dict, init=False,
                                   repr=False, compare=False)
    _memo_n: int = field(default=0, init=False, repr=False, compare=False)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, workloads, perf_model, sla_target: float,
              coverage: float = 0.90) -> "SlackPredictor":
        tables, dect = {}, {}
        for wl in workloads:
            tables[wl.name] = perf_model.profile_table(wl)
            dect[wl.name] = (wl.decode_dist.quantile(coverage)
                             if wl.decode_dist else 0)
        return cls(sla_target=sla_target, tables=tables, dec_timesteps=dect,
                   coverage=coverage)

    # ------------------------------------------------------------------
    def single_remaining(self, req: Request) -> float:
        """Conservative remaining single-batch execution time (Algorithm 1).

        Memoized per (request, progress) — the scheduler evaluates the same
        requests at every admission decision."""
        per = self._memo_get(req.rid)
        if req.idx in per:
            return per[req.idx]
        wl = req.workload
        table = self.tables[wl.name]
        dec = self.dec_timesteps.get(wl.name, 0)
        val = sum(table[nid]
                  for nid, _ctx in wl.predicted_remaining_nodes(req, dec))
        per[req.idx] = val
        self._memo_n += 1
        return val

    def single_total(self, req: Request) -> float:
        """SingleInputExecTime for a request that has not started (Eq. 1)."""
        wl = req.workload
        table = self.tables[wl.name]
        dec = self.dec_timesteps.get(wl.name, 0)
        if req.cycle_len:
            prefix = sum(table[nid] for nid, _ in req.sequence[:req.prefix_len])
            cycle = sum(table[nid] for nid in wl.cycle_ids())
            return prefix + dec * cycle
        return sum(table[nid] for nid, _ in req.sequence)

    def slack(self, req: Request, group: Iterable[Request], now: float) -> float:
        """Eq. 2 slack of ``req`` if batched with ``group`` (which includes
        req itself): SLA_req - T_wait - Σ_i SingleInputExecTime_i(remaining)."""
        t_wait = now - req.arrival
        total = sum(self.single_remaining(r) for r in group)
        return self.deadline(req) - t_wait - total

    # ------------------------------------------------------------------
    def authorize(self, ongoing: List[Request], pending: List[Request],
                  now: float) -> bool:
        """Authorize lazily batching ``pending`` with ``ongoing`` iff no
        request in the merged set is predicted to violate *its own* SLA
        (§IV-C: minimize violations first, throughput second)."""
        merged = list(ongoing) + list(pending)
        total = sum(self.single_remaining(r) for r in merged)
        for r in merged:
            if self.deadline(r) - (now - r.arrival) - total < 0.0:
                return False
        return True


@dataclass
class OracleSlackPredictor(_PredictorBase):
    """Oracular slack estimation (paper §VI design point 4).

    Uses (a) the *true* unrolled sequence lengths (no dec_timesteps
    overprovision) and (b) the precise batched latency-vs-throughput curve
    of every node (the NPU model evaluated at the merged batch size) instead
    of the conservative sum-of-singles bound.
    """
    sla_target: float
    perf_model: "object"        # serving.npu_model.NPUPerfModel
    # per-rid memo: {rid: {(idx, batch): seconds}} — evicted via forget()
    _memo: Dict[int, Dict] = field(default_factory=dict, init=False,
                                   repr=False, compare=False)
    _memo_n: int = field(default=0, init=False, repr=False, compare=False)
    _memo_cap = 2 * _MEMO_CAP          # (idx, batch) keys: more per request

    def _batched_remaining(self, req: Request, batch: int) -> float:
        per = self._memo_get(req.rid)
        key = (req.idx, batch)
        if key in per:
            return per[key]
        wl = req.workload
        val = sum(self.perf_model.node_latency(wl.nodes[nid], [ctx] * batch)
                  for nid, ctx in req.sequence[req.idx:])
        per[key] = val
        self._memo_n += 1
        return val

    def single_remaining(self, req: Request) -> float:
        return self._batched_remaining(req, 1)

    # an unstarted request's total IS its remaining time (idx == 0)
    single_total = single_remaining

    def slack(self, req: Request, group, now: float) -> float:
        group = list(group)
        return (self.deadline(req) - (now - req.arrival)
                - self._batched_remaining(req, len(group)))

    def authorize(self, ongoing: List[Request], pending: List[Request],
                  now: float) -> bool:
        merged = list(ongoing) + list(pending)
        n = len(merged)
        npend = len(pending)
        # catch-up phase: the pending sub-batch executes its own remaining
        # prefix (batched at |pending|) before it can merge with the ongoing
        # entries; ongoing requests are stalled for that long.
        catch = 0.0
        if pending:
            lead = pending[0]
            stop = lead.prefix_len if lead.cycle_len else len(lead.sequence)
            catch = sum(
                self.perf_model.node_latency(
                    lead.workload.nodes[nid], [ctx] * npend)
                for nid, ctx in lead.sequence[lead.idx:stop])
        for r in ongoing:
            finish = catch + self._batched_remaining(r, n)
            if (now - r.arrival) + finish > self.deadline(r):
                return False
        for p in pending:
            if (now - p.arrival) + self._batched_remaining(p, n) > self.deadline(p):
                return False
        return True
