"""SLA-aware slack time prediction (paper §IV-C, Eq. 1-2, Algorithm 1).

    Slack_r = SLA_target - (T_wait_r + Σ_{i in batch} SingleInputExecTime_i)

Deliberately conservative: the latency of a batch is overestimated as the
*sum* of its members' isolated single-batch latencies, so estimated slack
shrinks and SLA violations are minimized first, throughput second.

SingleInputExecTime_i comes from the profiled per-node latency lookup table
(``NodeLatency(n)``); dynamic graphs are overprovisioned with
``dec_timesteps`` = the N-% quantile of the output-length distribution
(default N = 90%, paper Fig. 11).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from .request import Request


@dataclass
class SlackPredictor:
    sla_target: float
    # per-workload-name profiled node latency tables (single-batch)
    tables: Dict[str, Dict[str, float]]
    # per-workload-name dec_timesteps (quantile of decode-length profile)
    dec_timesteps: Dict[str, int]
    coverage: float = 0.90

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, workloads, perf_model, sla_target: float,
              coverage: float = 0.90) -> "SlackPredictor":
        tables, dect = {}, {}
        for wl in workloads:
            tables[wl.name] = perf_model.profile_table(wl)
            dect[wl.name] = (wl.decode_dist.quantile(coverage)
                             if wl.decode_dist else 0)
        return cls(sla_target=sla_target, tables=tables, dec_timesteps=dect,
                   coverage=coverage)

    # ------------------------------------------------------------------
    def single_remaining(self, req: Request) -> float:
        """Conservative remaining single-batch execution time (Algorithm 1).

        Memoized per (request, progress) — the scheduler evaluates the same
        requests at every admission decision."""
        key = (req.rid, req.idx)
        cache = getattr(self, "_memo", None)
        if cache is None:
            cache = self._memo = {}
        if key in cache:
            return cache[key]
        wl = req.workload
        table = self.tables[wl.name]
        dec = self.dec_timesteps.get(wl.name, 0)
        val = sum(table[nid]
                  for nid, _ctx in wl.predicted_remaining_nodes(req, dec))
        cache[key] = val
        if len(cache) > 100_000:
            cache.clear()
        return val

    def single_total(self, req: Request) -> float:
        """SingleInputExecTime for a request that has not started (Eq. 1)."""
        wl = req.workload
        table = self.tables[wl.name]
        dec = self.dec_timesteps.get(wl.name, 0)
        if req.cycle_len:
            prefix = sum(table[nid] for nid, _ in req.sequence[:req.prefix_len])
            cycle = sum(table[nid] for nid in wl.cycle_ids())
            return prefix + dec * cycle
        return sum(table[nid] for nid, _ in req.sequence)

    def slack(self, req: Request, group: Iterable[Request], now: float) -> float:
        """Eq. 2 slack of ``req`` if batched with ``group`` (which includes
        req itself): SLA - T_wait - Σ_i SingleInputExecTime_i(remaining)."""
        t_wait = now - req.arrival
        total = sum(self.single_remaining(r) for r in group)
        return self.sla_target - t_wait - total

    # ------------------------------------------------------------------
    def authorize(self, ongoing: List[Request], pending: List[Request],
                  now: float) -> bool:
        """Authorize lazily batching ``pending`` with ``ongoing`` iff no
        request in the merged set is predicted to violate its SLA (§IV-C:
        minimize violations first, throughput second)."""
        merged = list(ongoing) + list(pending)
        total = sum(self.single_remaining(r) for r in merged)
        for r in merged:
            if self.sla_target - (now - r.arrival) - total < 0.0:
                return False
        return True


@dataclass
class OracleSlackPredictor:
    """Oracular slack estimation (paper §VI design point 4).

    Uses (a) the *true* unrolled sequence lengths (no dec_timesteps
    overprovision) and (b) the precise batched latency-vs-throughput curve
    of every node (the NPU model evaluated at the merged batch size) instead
    of the conservative sum-of-singles bound.
    """
    sla_target: float
    perf_model: "object"        # serving.npu_model.NPUPerfModel

    def _batched_remaining(self, req: Request, batch: int) -> float:
        key = (req.rid, req.idx, batch)
        cache = getattr(self, "_memo", None)
        if cache is None:
            cache = self._memo = {}
        if key in cache:
            return cache[key]
        wl = req.workload
        val = sum(self.perf_model.node_latency(wl.nodes[nid], [ctx] * batch)
                  for nid, ctx in req.sequence[req.idx:])
        cache[key] = val
        if len(cache) > 200_000:
            cache.clear()
        return val

    def single_remaining(self, req: Request) -> float:
        return self._batched_remaining(req, 1)

    def slack(self, req: Request, group, now: float) -> float:
        group = list(group)
        return (self.sla_target - (now - req.arrival)
                - self._batched_remaining(req, len(group)))

    def authorize(self, ongoing: List[Request], pending: List[Request],
                  now: float) -> bool:
        merged = list(ongoing) + list(pending)
        n = len(merged)
        npend = len(pending)
        # catch-up phase: the pending sub-batch executes its own remaining
        # prefix (batched at |pending|) before it can merge with the ongoing
        # entries; ongoing requests are stalled for that long.
        catch = 0.0
        if pending:
            lead = pending[0]
            stop = lead.prefix_len if lead.cycle_len else len(lead.sequence)
            catch = sum(
                self.perf_model.node_latency(
                    lead.workload.nodes[nid], [ctx] * npend)
                for nid, ctx in lead.sequence[lead.idx:stop])
        for r in ongoing:
            finish = catch + self._batched_remaining(r, n)
            if (now - r.arrival) + finish > self.sla_target:
                return False
        for p in pending:
            if (now - p.arrival) + self._batched_remaining(p, n) > self.sla_target:
                return False
        return True
