"""Inference requests and sub-batches (BatchTable entries).

A request's execution is a linear sequence of graph nodes (paper §II-A:
the DAG is lowered to a serialized node-wise execution order; dynamic
seq2seq graphs are unrolled per-request into their actual length). Node ids
are *shared* across unroll steps when the underlying weights are shared
(RNN cells, decode-cycle layers) — two requests at the same node id can be
merged into one sub-batch regardless of their absolute timestep, which is
exactly the property cellular batching exploits and LazyBatching
generalizes.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from . import lifecycle

_rid_counter = itertools.count()


@dataclass(frozen=True)
class SLAClass:
    """Per-request service tier: a latency deadline plus a reporting name.

    The deadline is *relative* (seconds from arrival to completion) — the
    same quantity the paper's single global ``SLA_target`` froze at
    predictor-build time. Requests without an ``sla`` fall back to that
    global scalar, so single-tier serving is byte-identical to before;
    mixed-tier traces attach different classes per request and the slack
    predictors / LazyBatching admission honor each request's own deadline.
    """
    name: str = "default"
    deadline: float = 0.1

    def __post_init__(self):
        if not self.deadline > 0.0:
            raise ValueError(
                f"SLA class {self.name!r} deadline must be positive, "
                f"got {self.deadline!r}")


@dataclass
class Request:
    workload: "object"                  # serving.workload.Workload
    arrival: float
    sequence: List[Tuple[str, int]]     # [(node_id, ctx), ...]
    rid: int = field(default_factory=lambda: next(_rid_counter))
    idx: int = 0                        # next node to execute
    sla: Optional[SLAClass] = None      # None = predictor's global target
    # registry model tag: which registered model serves this request
    # (stamped by traffic.poisson_mixture and by multi-model
    # ServingSession.submit; None falls back to the workload's own name
    # for per-model reporting)
    model: Optional[str] = None
    # terminal out-of-band disposition (None = normal lifecycle): one of
    # core.lifecycle.FATES — "cancelled" (caller), "expired" (deadline
    # provably blown mid-flight), "failed" (backend fault, retries
    # exhausted), "shed" (load shedding). A fated request is dead to the
    # scheduler: SubBatch live-filtering drops it exactly like a finished
    # one, but it never gets a t_finish. Writes are validated against the
    # lifecycle table (see __setattr__): only declared fates, and a fate
    # is absorbing — it can never be overwritten with a different one.
    fate: Optional[str] = None
    retries: int = 0                    # fault-retry attempts so far
    t_first_issue: Optional[float] = None
    # stamped by the session at the run boundary emitting token #1:
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    # sequence-structure metadata (set by Workload.sample_request)
    prompt_len: int = 0
    decode_len: int = 0
    prefix_len: int = 0                 # node count before the decode cycles
    cycle_len: int = 0                  # nodes per decode cycle (0 = static)

    def __setattr__(self, name, value):
        # fate writes are lifecycle edges: enforce the declarative table
        # (core.lifecycle) at runtime — the handle-lattice static checker
        # polices the same table at review time
        if name == "fate" and value is not None:
            if value not in lifecycle.FATES:
                raise ValueError(
                    f"request {self.__dict__.get('rid', '?')}: fate "
                    f"{value!r} is not a declared terminal disposition "
                    f"(lifecycle.FATES={lifecycle.FATES})")
            cur = self.__dict__.get("fate")
            if cur is not None and cur != value:
                raise RuntimeError(
                    f"request {self.__dict__.get('rid', '?')}: fate is "
                    f"absorbing — cannot move {cur!r} -> {value!r} "
                    f"(terminal states have no out-edges)")
        super().__setattr__(name, value)

    @property
    def done(self) -> bool:
        return self.idx >= len(self.sequence)

    @property
    def terminal(self) -> bool:
        """Finished OR removed from service (cancelled/expired/failed/
        shed) — either way the scheduler never dispatches it again."""
        return self.done or self.fate is not None

    @property
    def next_node_id(self) -> Optional[str]:
        if self.done:
            return None
        return self.sequence[self.idx][0]

    @property
    def next_ctx(self) -> int:
        return self.sequence[self.idx][1]

    def advance(self):
        if self.done:
            raise RuntimeError(
                f"request {self.rid} advanced past its final node "
                f"(idx={self.idx}, sequence length {len(self.sequence)})")
        self.idx += 1

    def latency(self) -> float:
        if self.t_finish is None:
            raise RuntimeError(
                f"request {self.rid} has no latency yet — it has not "
                f"finished (idx={self.idx}/{len(self.sequence)})")
        return self.t_finish - self.arrival

    def clone(self) -> "Request":
        """Fresh, unexecuted copy (for comparing policies on one trace)."""
        return Request(workload=self.workload, arrival=self.arrival,
                       sequence=self.sequence, rid=self.rid, sla=self.sla,
                       model=self.model,
                       prompt_len=self.prompt_len, decode_len=self.decode_len,
                       prefix_len=self.prefix_len, cycle_len=self.cycle_len)

    @property
    def sla_name(self) -> str:
        return self.sla.name if self.sla is not None else "default"

    @property
    def model_name(self) -> str:
        """Reporting key for per-model breakdowns: the registry tag when
        the request was routed through one, else its workload's name."""
        if self.model is not None:
            return self.model
        return getattr(self.workload, "name", "default")

    @property
    def n_tokens(self) -> int:
        """Response tokens a completed request produced (one per decode
        cycle; a static graph's single response counts as one)."""
        if self.cycle_len:
            return max(0, self.idx - self.prefix_len) // self.cycle_len
        return 1 if self.done else 0

    def __repr__(self):
        return (f"Request(rid={self.rid}, wl={getattr(self.workload, 'name', '?')}, "
                f"idx={self.idx}/{len(self.sequence)})")


@dataclass
class SubBatch:
    """One BatchTable stack entry: requests advancing in lockstep.

    Invariant: all member requests share the same ``next_node_id`` (they are
    at a common graph node). Members may *complete* at different times
    (variable unrolled lengths) — finished requests simply leave the batch.
    """
    requests: List[Request]

    @property
    def node_id(self) -> Optional[str]:
        live = [r for r in self.requests if not r.terminal]
        if not live:
            return None
        nid = live[0].next_node_id
        if any(r.next_node_id != nid for r in live):
            raise RuntimeError(
                "SubBatch invariant violated: members at different nodes "
                + str(sorted({str(r.next_node_id) for r in live})))
        return nid

    @property
    def live_requests(self) -> List[Request]:
        # fated (cancelled/expired/failed/shed) members fall out exactly
        # like finished ones — the session evicts them physically at run
        # boundaries; this filter makes any missed path fail-safe instead
        # of dispatching a dead request
        return [r for r in self.requests if not r.terminal]

    @property
    def size(self) -> int:
        return len(self.live_requests)

    def advance(self, now: float) -> List[Request]:
        """Advance every live member one node; return newly finished."""
        return self.advance_n(1, now)

    def advance_n(self, n: int, now: float) -> List[Request]:
        """Advance every live member ``n`` nodes (one committed run);
        return newly finished requests. ``n`` must not exceed any member's
        remaining node count — runs are committed via :meth:`run_nodes`,
        which caps at the earliest-finishing member."""
        finished = []
        for r in self.live_requests:
            for _ in range(n):
                r.advance()
            if r.done:
                r.t_finish = now
                finished.append(r)
        self.requests = self.live_requests
        return finished

    def run_nodes(self, *, stop_before=(), stop_after=()) -> Tuple[str, ...]:
        """Maximal run of consecutive node ids the batch can commit.

        All live members share the same forward node-id stream from their
        common current node (same workload, shared cycle ids), so the run is
        read off any member and capped at ``min`` remaining nodes — no
        member ever finishes *mid*-run, only exactly at a run boundary.

        ``stop_before``: node ids the run must not enter (the entry below
        on the BatchTable stack sits at such a node — stopping there keeps
        every merge opportunity a single-node scheduler would have seen).
        ``stop_after``: node ids the run ends on *inclusively* (decode-cycle
        boundaries — the scheduler re-evaluates admission/preemption there).
        The first node is always included: a single-node run is the
        degenerate (always valid) case.
        """
        live = self.live_requests
        n = min(len(r.sequence) - r.idx for r in live)
        r0 = live[0]
        ids = [nid for nid, _ in r0.sequence[r0.idx:r0.idx + n]]
        run = [ids[0]]
        for nid in ids[1:]:
            if nid in stop_before:
                break
            run.append(nid)
            if nid in stop_after:
                break
        return tuple(run)

    def mergeable_with(self, other: "SubBatch", max_batch: int) -> bool:
        a, b = self.node_id, other.node_id
        if a is None or a != b or self.size + other.size > max_batch:
            return False
        # co-location: node ids only denote shared weights within ONE model —
        # sub-batches of different workloads never merge (§VI-C)
        return (self.live_requests[0].workload
                is other.live_requests[0].workload)

    def merge(self, other: "SubBatch"):
        if self.node_id != other.node_id:
            raise RuntimeError(
                f"cannot merge sub-batches at different nodes: "
                f"{self.node_id!r} vs {other.node_id!r} — merge_top must "
                f"check mergeable_with first")
        self.requests = self.live_requests + other.live_requests
