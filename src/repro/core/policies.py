"""Scheduling / batching policies (paper §VI design points).

  * ``Serial``      — no batching, FIFO, one request at a time.
  * ``GraphBatching(window, max_batch)`` — the baseline: whole-graph batches
    formed by a static batching time-window + model-allowed max batch size.
  * ``CellularBatching`` — application-specific baseline [Gao et al.]:
    node-level interleaving but merges permitted only at weight-shared
    *cell* nodes; no SLA awareness. Degenerates to graph-like serialization
    on workloads without cell nodes (paper Fig. 7).
  * ``LazyBatching``  — the paper's contribution: BatchTable stack +
    SLA-aware conservative slack prediction.
  * ``Oracle``        — LazyBatching with the oracular latency-vs-batch
    tradeoff curves and true decode lengths.

All policies speak one interface consumed by both the discrete-event
simulator and the real-JAX serving engine:

    enqueue(req, now); next_work(now) -> (SubBatch, run) | None;
    work_done(sub_batch, now, n_nodes) -> finished requests; next_timer(now).

``run`` is a tuple of *consecutive* node ids committed for dispatch in one
go (the run-commit contract): the scheduler decides per node but commits
the maximal span during which no scheduling decision could change the
outcome, so the executor may fuse the whole run into one device dispatch.
Each policy commits exactly the span to its next possible merge /
preemption point:

  * ``Serial`` / ``GraphBatching`` never merge into or preempt a running
    batch — they commit whole remaining graphs (capped at the
    earliest-finishing member, so completions stay run-boundary events);
  * ``CellularBatching`` / ``LazyBatching`` stop *before* the node the
    stack entry below is parked at (where a catch-up merge is possible —
    for cellular only when that node is a weight-shared cell) and stop
    *after* each decode-cycle boundary, the point where admission and
    preemption are re-evaluated. On static (non-cyclic) graphs they keep
    single-node commits: the paper's node granularity, unchanged.

A single-node run is always a valid degenerate commit.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import List, Optional, Tuple

from .batch_table import BatchTable
from .request import Request, SubBatch
from .slack import SlackPredictor

Work = Tuple[SubBatch, Tuple[str, ...]]


def _group_pushable(reqs: List[Request]) -> List[List[Request]]:
    """Split a request list into SubBatch-compatible groups: same workload
    AND same next node (co-located models never share a sub-batch)."""
    groups: dict = {}
    for r in reqs:
        groups.setdefault((id(r.workload), r.next_node_id), []).append(r)
    return list(groups.values())


class Policy:
    name = "abstract"

    # memory-aware admission hook, wired by the serving session when the
    # backend reports a bounded KV pool: a callable returning how many NEW
    # requests this policy may admit right now without oversubscribing
    # device memory (None = unbounded / memory-blind — the seed behavior).
    # Policies that honor it defer admission (requests wait in the InfQ,
    # burning slack like any other wait) instead of overcommitting; the
    # whole-graph baselines (Serial/GraphBatching) stay memory-blind.
    mem_gate = None

    def __init__(self, max_batch: int = 64):
        self.max_batch = max_batch
        self.queue: deque[Request] = deque()

    def enqueue(self, req: Request, now: float):
        self.queue.append(req)

    def _mem_room(self) -> Optional[int]:
        """New admissions the memory gate allows now (None = unbounded —
        no gate wired, or the backend reports no memory cap)."""
        if self.mem_gate is None:
            return None
        room = self.mem_gate()
        return None if room is None else max(0, room)

    @property
    def admitted_requests(self) -> List[Request]:
        """Live requests admitted out of the InfQ (each holds — or is
        about to hold — one KV slot until it finishes)."""
        return []

    @property
    def admitted(self) -> int:
        return len(self.admitted_requests)

    def next_work(self, now: float) -> Optional[Work]:
        raise NotImplementedError

    def commit_run(self, sb: SubBatch) -> Tuple[str, ...]:
        """Run of node ids committed for the active batch (degenerate
        default: one node — correct for any policy, fuses nothing)."""
        return (sb.node_id,)

    def work_done(self, sb: SubBatch, now: float,
                  n_nodes: int = 1) -> List[Request]:
        raise NotImplementedError

    def request_finished(self, reqs: List[Request]) -> None:
        """Completion hook: the serving session reports every request that
        finished at the last run boundary, so policies can release
        per-request scheduling state (e.g. slack-predictor memo entries).
        Default no-op."""

    def cancel(self, reqs: List[Request]) -> None:
        """Evict ``reqs`` from this policy's scheduling state mid-flight
        (cancellation / expiry / fault-retry requeue): drop them from the
        InfQ and physically remove them from any batch entry, pruning
        entries that empty out — the same live-filtering / drop-empty
        machinery that removes finished members at run boundaries, so
        surviving batch members are untouched. Only called at run
        boundaries (never while a run is in flight). Idempotent: unknown
        rids are ignored."""
        gone = {r.rid for r in reqs}
        if any(r.rid in gone for r in self.queue):
            self.queue = deque(r for r in self.queue if r.rid not in gone)
        self._evict_batched(gone)

    def _evict_batched(self, gone: set) -> None:
        """Hook: remove ``gone`` rids from the policy's batch state."""

    def next_timer(self, now: float) -> Optional[float]:
        return None

    @property
    def outstanding(self) -> int:
        raise NotImplementedError


class Serial(Policy):
    name = "serial"

    def __init__(self):
        super().__init__(max_batch=1)
        self.active: Optional[SubBatch] = None

    def next_work(self, now):
        if self.active is None or self.active.size == 0:
            if not self.queue:
                return None
            req = self.queue.popleft()
            req.t_first_issue = now
            self.active = SubBatch([req])
        return self.active, self.commit_run(self.active)

    def commit_run(self, sb):
        # no batching, no merging: the whole remaining graph is one run
        return sb.run_nodes()

    def work_done(self, sb, now, n_nodes=1):
        finished = sb.advance_n(n_nodes, now)
        if sb.size == 0:
            self.active = None
        return finished

    def _evict_batched(self, gone):
        if self.active is not None:
            self.active.requests = [r for r in self.active.requests
                                    if r.rid not in gone]
            if self.active.size == 0:
                self.active = None

    @property
    def admitted_requests(self):
        return self.active.live_requests if self.active else []

    @property
    def outstanding(self):
        return len(self.queue) + (self.active.size if self.active else 0)


class GraphBatching(Policy):
    def __init__(self, window: float, max_batch: int = 64):
        super().__init__(max_batch=max_batch)
        self.window = window
        self.active: Optional[SubBatch] = None
        self.name = f"graphb({window * 1e3:g}ms)"

    def _head_group(self) -> List[Request]:
        """Oldest request + up to max_batch-1 same-model followers (per-model
        graph batches — co-located models are never batched together)."""
        head = self.queue[0]
        group = [r for r in self.queue if r.workload is head.workload]
        return group[:self.max_batch]

    def _batch_ready(self, now) -> bool:
        if not self.queue:
            return False
        return (len(self._head_group()) >= self.max_batch
                or now + 1e-12 >= self.queue[0].arrival + self.window)

    def next_work(self, now):
        if self.active is not None and self.active.size:
            return self.active, self.commit_run(self.active)
        if not self._batch_ready(now):
            return None
        reqs = self._head_group()
        for r in reqs:
            self.queue.remove(r)
            r.t_first_issue = now
        self.active = SubBatch(reqs)
        return self.active, self.commit_run(self.active)

    def commit_run(self, sb):
        # whole-graph batches never merge mid-flight or preempt: commit the
        # full remaining segment (capped at the earliest-finishing member)
        return sb.run_nodes()

    def work_done(self, sb, now, n_nodes=1):
        finished = sb.advance_n(n_nodes, now)
        if sb.size == 0:
            self.active = None
        return finished

    def _evict_batched(self, gone):
        if self.active is not None:
            self.active.requests = [r for r in self.active.requests
                                    if r.rid not in gone]
            if self.active.size == 0:
                self.active = None

    def next_timer(self, now):
        if self.queue and (self.active is None or self.active.size == 0):
            return self.queue[0].arrival + self.window
        return None

    @property
    def admitted_requests(self):
        return self.active.live_requests if self.active else []

    @property
    def outstanding(self):
        return len(self.queue) + (self.active.size if self.active else 0)


class _TableBased(Policy):
    """Shared machinery for node-level interleaving policies."""

    def __init__(self, max_batch: int = 64):
        super().__init__(max_batch=max_batch)
        self.table = BatchTable(max_batch=max_batch)

    # optional callable(top, below) -> bool restricting merges beyond the
    # structural BatchTable rule (None = paper LazyBatching: always merge)
    merge_predicate = None

    def _merge_top(self):
        """Merge the topmost entries subject to the policy's merge rule."""
        self.table.merge_top(self.merge_predicate)
        self.table.pop_if_done()

    def _admit(self, now: float):
        raise NotImplementedError

    def _select_active(self, now: float):
        """Hook: reorder the stack before dispatch (default: paper LIFO)."""

    def next_work(self, now):
        self._merge_top()
        self._admit(now)
        self._merge_top()
        self._select_active(now)
        active = self.table.active
        if active is None or active.size == 0:
            return None
        return active, self.commit_run(active)

    # does reaching ``node_id`` open a merge opportunity for this policy?
    # (LazyBatching merges at any shared node — paper §IV-B)
    def _merge_possible_at(self, wl, node_id: str) -> bool:
        return True

    def commit_run(self, sb):
        """Span to the next possible merge / preemption point.

        Static graphs keep the paper's single-node granularity (admission
        and preemption are re-evaluated at every layer). Cyclic graphs
        commit at most one *segment* — a run ends at every segment-final
        node (the prefill/decode boundary and each decode cycle's last
        node), the iteration-level points where admission, preemption, and
        SLA slack are re-checked, so the slack burned by a committed run is
        bounded by one prefill segment or one decode cycle (inside the
        predictor's dec_timesteps overprovision) — and always stops
        *before* the node the stack entry directly below is parked at,
        where a catch-up merge could fire.
        """
        wl = sb.live_requests[0].workload
        if wl.cycle_end_id() is None:
            return (sb.node_id,)
        stop_before = set()
        stack = self.table.stack
        if len(stack) >= 2:
            below = stack[-2]
            if (below.size
                    and below.live_requests[0].workload is wl
                    and self._merge_possible_at(wl, below.node_id)):
                stop_before.add(below.node_id)
        return sb.run_nodes(stop_before=stop_before,
                            stop_after=wl.commit_boundaries())

    def work_done(self, sb, now, n_nodes=1):
        finished = sb.advance_n(n_nodes, now)
        self._merge_top()
        return finished

    def _evict_batched(self, gone):
        for sb in self.table.stack:
            sb.requests = [r for r in sb.requests if r.rid not in gone]
        self.table._drop_empty()

    @property
    def admitted_requests(self):
        return self.table.all_requests()

    @property
    def outstanding(self):
        return len(self.queue) + self.table.total_size


class CellularBatching(_TableBased):
    name = "cellular"

    @staticmethod
    def merge_predicate(top, below):
        # application-specific baseline: merges permitted only at
        # weight-shared *cell* nodes [Gao et al.]
        wl = top.live_requests[0].workload
        return wl.nodes[top.node_id].cell

    def _merge_possible_at(self, wl, node_id):
        return wl.nodes[node_id].cell

    def _admit(self, now):
        # iteration-level scheduling: admit new requests unconditionally at
        # node boundaries (no SLA model); capacity- and memory-bounded
        room = self.max_batch - self.table.total_size
        mem = self._mem_room()
        if mem is not None:
            room = min(room, mem)
        if room <= 0 or not self.queue:
            return
        take = min(room, len(self.queue))
        reqs = [self.queue.popleft() for _ in range(take)]
        for r in reqs:
            r.t_first_issue = now
        for group in _group_pushable(reqs):
            self.table.push(group)


class LazyBatching(_TableBased):
    """The paper's SLA-aware node-level batching system."""
    name = "lazyb"

    def __init__(self, predictor: SlackPredictor, max_batch: int = 64):
        super().__init__(max_batch=max_batch)
        self.predictor = predictor
        self.n_preemptions = 0
        self.n_rejections = 0

    def request_finished(self, reqs):
        # evict the predictor's per-request memo entries (unbounded growth
        # otherwise: every (rid, idx) ever evaluated stayed cached)
        forget = getattr(self.predictor, "forget", None)
        if forget is not None:
            for r in reqs:
                forget(r.rid)

    def _select_active(self, now):
        """Paper LIFO preserved: the newest entry must run so it can catch
        up and merge (urgency-first dispatch was tried and REFUTED — it
        breaks the catch-up mechanism and serialized everything; see
        EXPERIMENTS.md §Paper-validation co-location notes). Only
        exception: an entry whose slack has gone negative while a
        *different-model* entry is on top gets promoted once — bounded
        anti-starvation for co-location, unreachable in single-model
        serving."""
        stack = self.table.stack
        if len(stack) < 2:
            return
        top_wl = stack[-1].live_requests[0].workload
        for i in range(len(stack) - 1):
            sb = stack[i]
            if sb.live_requests[0].workload is top_wl:
                continue
            slack = min(self.predictor.slack(r, [r], now)
                        for r in sb.live_requests)
            if slack < 0.0:
                stack.append(stack.pop(i))
                self.n_preemptions += 1
                return

    def _edf_take(self, candidates: List[Request], k: int) -> List[Request]:
        """The ``k`` earliest-absolute-deadline candidates (arrival + the
        request's own SLA-class deadline). ``nsmallest`` is stable, so with
        a single class (constant deadline) this is exactly the FIFO prefix;
        O(n log k) instead of a full sort."""
        return heapq.nsmallest(
            k, candidates, key=lambda r: r.arrival + self.predictor.deadline(r))

    def _take_from_queue(self, reqs: List[Request], now: float) -> None:
        """Remove ``reqs`` from the InfQ in one pass and stamp first issue."""
        taken = {r.rid for r in reqs}
        self.queue = deque(r for r in self.queue if r.rid not in taken)
        for r in reqs:
            r.t_first_issue = now

    def _admit(self, now):
        if not self.queue:
            return
        # memory-aware mode (session-wired gate): never admit more new
        # requests than free KV slots — the overflow defers in the InfQ
        # (burning slack exactly like any other wait, so EDF order still
        # decides who gets a slot when one frees) instead of overcommitting
        # device memory. Gate unset = the paper's memory-blind admission.
        mem = self._mem_room()
        ongoing = self.table.all_requests()
        if not ongoing:
            # idle processor: schedule immediately (no batching conflict);
            # earliest-absolute-deadline first when the backlog exceeds
            # max_batch (== FIFO for a single SLA class)
            cap = self.max_batch if mem is None else min(self.max_batch, mem)
            if cap <= 0:
                return
            reqs = self._edf_take(self.queue, cap)
            self._take_from_queue(reqs, now)
            for group in _group_pushable(reqs):
                self.table.push(group)
            return
        room = self.max_batch - len(ongoing)
        if mem is not None:
            room = min(room, mem)
        if room <= 0:
            return
        # largest authorized deadline-ordered prefix (adding requests only
        # shrinks slack, so feasibility is monotone in the prefix length):
        # earliest-deadline-first across mixed tiers, identical to FIFO when
        # every request shares the global target. Under co-location the
        # prefix is drawn from the head request's model only: admitting a
        # same-model group preserves merge opportunities, while interleaving
        # models per admission only deepens the stack (§VI-C).
        head_wl = self.queue[0].workload
        candidates = [r for r in self.queue if r.workload is head_wl]
        pending = self._edf_take(candidates, min(room, len(candidates)))
        # Cross-model preemption has no merge upside (sub-batches of
        # different models never share a node): only preempt for a foreign
        # model when its head is more urgent than every ongoing request —
        # otherwise it waits its turn in the InfQ (beyond-paper refinement
        # of §VI-C co-location; no effect on single-model serving).
        if pending and all(r.workload is not head_wl for r in ongoing):
            head_urgency = self.predictor.slack(pending[0], [pending[0]], now)
            ongoing_urgency = min(self.predictor.slack(r, [r], now)
                                  for r in ongoing)
            # defer only while the head can still afford to wait — under
            # heavy load its slack burns down and it gets admitted, so no
            # model can head-of-line-block the others
            if head_urgency > max(ongoing_urgency, 0.0):
                self.n_rejections += 1
                return
        while pending:
            if self.predictor.authorize(ongoing, pending, now):
                break
            pending = pending[:-1]
        if not pending:
            self.n_rejections += 1
            return
        self._take_from_queue(pending, now)
        self.n_preemptions += 1
        for group in _group_pushable(pending):
            self.table.push(group)


class Oracle(LazyBatching):
    """LazyBatching driven by oracular latency knowledge (paper §VI)."""
    name = "oracle"
