"""Stack-based batch state table (paper §IV-B, Fig. 10).

The entry at the top of the stack is the *active batch* currently executing.
Pushing preempts the active batch; when the top two entries reach the same
graph node they are merged into a single entry. All operations happen at
node (layer) boundaries, in software — O(1) scheduling, no hardware change.
"""
from __future__ import annotations

from typing import List, Optional

from .request import Request, SubBatch


class BatchTable:
    def __init__(self, max_batch: int = 64):
        self.stack: List[SubBatch] = []     # index -1 == top == active batch
        self.max_batch = max_batch

    # ------------------------------------------------------------------
    @property
    def active(self) -> Optional[SubBatch]:
        return self.stack[-1] if self.stack else None

    @property
    def empty(self) -> bool:
        return not self.stack

    @property
    def num_entries(self) -> int:
        return len(self.stack)

    def all_requests(self) -> List[Request]:
        return [r for sb in self.stack for r in sb.live_requests]

    @property
    def total_size(self) -> int:
        return sum(sb.size for sb in self.stack)

    # ------------------------------------------------------------------
    def push(self, requests: List[Request]) -> SubBatch:
        """Preempt the active batch and make ``requests`` the new active one."""
        sb = SubBatch(list(requests))
        self.stack.append(sb)
        return sb

    def merge_top(self, predicate=None) -> int:
        """Merge the topmost entries while they share a node id (Fig. 10 t=6).

        ``predicate`` (optional ``callable(top, below) -> bool``) lets a
        policy further restrict merges beyond the structural
        ``mergeable_with`` rule — e.g. cellular batching only merges at
        weight-shared *cell* nodes. Returns the number of merges performed.
        """
        merges = 0
        while len(self.stack) >= 2:
            top, below = self.stack[-1], self.stack[-2]
            if top.size == 0:
                self.stack.pop()
                continue
            if below.size == 0:
                del self.stack[-2]
                continue
            if not top.mergeable_with(below, self.max_batch):
                break
            if predicate is not None and not predicate(top, below):
                break
            below.merge(top)
            self.stack.pop()
            merges += 1
        self._drop_empty()
        return merges

    def _drop_empty(self):
        self.stack = [sb for sb in self.stack if sb.size > 0]

    def pop_if_done(self):
        while self.stack and self.stack[-1].size == 0:
            self.stack.pop()

    def __repr__(self):
        rows = [f"  [{i}] node={sb.node_id} rids={[r.rid for r in sb.live_requests]}"
                for i, sb in enumerate(self.stack)]
        return "BatchTable(\n" + "\n".join(rows) + ")"
