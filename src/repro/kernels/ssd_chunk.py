"""Mamba-2 SSD intra-chunk kernel.

The quadratic within-chunk term of the SSD algorithm (arXiv:2405.21060) is
the compute hot-spot of Mamba-2 prefill: for every (batch, chunk, head)
cell it builds the decay-weighted score matrix and applies it to the chunk.
This kernel fuses the whole cell — decay cumsum, L matrix, C·Bᵀ scores,
weighted PV product, and the chunk summary state — into one VMEM-resident
block (no (chunk × chunk × heads) L tensor ever hits HBM, which is what
the pure-jnp reference materializes).

grid = (B, n_chunks, n_heads); per cell:
  x (chunk, hd), dt (chunk,), B/C (chunk, N) -> y_intra (chunk, hd),
  state (hd, N), exp(cum) (chunk,), exp(total) (1,).
The inter-chunk linear recurrence stays outside in
``jax.lax.associative_scan`` (log-depth — the TPU adaptation of the
sequential CUDA inter-chunk pass, DESIGN.md §3).

VMEM per cell at chunk=256, hd=64, N=128: x 64KB + B/C 2·128KB + L/scores
2·256KB f32 ≈ 0.9 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            y_ref, st_ref, cume_ref, dec_ref):
    chunk, hd = x_ref.shape[2], x_ref.shape[4]
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)      # (chunk, hd)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)       # (chunk,)
    A = a_ref[0].astype(jnp.float32)                  # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)              # (chunk, N)
    Cm = c_ref[0, 0].astype(jnp.float32)              # (chunk, N)

    dA = dt * A
    cum = jnp.cumsum(dA)                              # (chunk,)
    total = cum[-1]

    # intra-chunk: y_i = sum_{j<=i} exp(cum_i - cum_j) * dt_j * (C_i·B_j) x_j
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))
    w = scores * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))

    # chunk summary state: S = sum_j exp(total - cum_j) dt_j x_j B_j^T
    decay_to_end = jnp.exp(total - cum)
    xw = x * (decay_to_end * dt)[:, None]
    st = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())))  # (hd, N)

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = st
    cume_ref[0, 0, :, 0] = jnp.exp(cum)
    dec_ref[0, 0, 0] = jnp.exp(total)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_intra(x, dt, A, B_ssm, C_ssm, *, chunk: int,
                    interpret: bool | None = None):
    """Intra-chunk SSD terms.

    x: (B, S, nh, hd); dt: (B, S, nh) post-softplus; A: (nh,) negative;
    B_ssm, C_ssm: (B, S, N). Returns
    (y_intra (B,S,nh,hd), states (B,nc,nh,hd,N),
     cum_exp (B,S,nh), decay (B,nc,nh)).
    """
    Bb, S, nh, hd = x.shape
    N = B_ssm.shape[-1]
    if S % chunk != 0:
        raise ValueError(
            f"ssd_chunk_scan: sequence length S={S} must be a multiple "
            f"of chunk={chunk} — pad the sequence or shrink the chunk")
    nc = S // chunk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    xc = x.reshape(Bb, nc, chunk, nh, hd)
    dtc = dt.reshape(Bb, nc, chunk, nh)
    Bc = B_ssm.reshape(Bb, nc, chunk, N)
    Cc = C_ssm.reshape(Bb, nc, chunk, N)

    y, st, cume, dec = pl.pallas_call(
        _kernel,
        grid=(Bb, nc, nh),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, hd), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, c, h: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, hd), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, hd, N), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, 1), lambda b, c, h: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, nc, chunk, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((Bb, nc, nh, hd, N), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, chunk, nh), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, nh), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc)
    return (y.reshape(Bb, S, nh, hd), st,
            cume.reshape(Bb, S, nh), dec)


def ssd_chunked_pallas(x, dt, A, B_ssm, C_ssm, chunk: int,
                       interpret: bool | None = None):
    """Drop-in replacement for ``repro.models.ssm.ssd_chunked`` with the
    intra-chunk work in the Pallas kernel and the inter-chunk recurrence in
    ``jax.lax.associative_scan``. Returns (y (B,S,nh,hd), final_state)."""
    Bb, S, nh, hd = x.shape
    N = B_ssm.shape[-1]
    nc = S // chunk

    y_intra, states, cum_exp, decay = ssd_chunk_intra(
        x, dt, A, B_ssm, C_ssm, chunk=chunk, interpret=interpret)

    def combine(a, b):
        da, sa = a
        db, sb = b
        return da * db, sa * db[..., None, None] + sb

    dec_s, st_s = jax.lax.associative_scan(
        combine, (decay, states), axis=1)
    h_prev = jnp.pad(st_s[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))

    Cc = C_ssm.reshape(Bb, nc, chunk, N)
    cume_c = cum_exp.reshape(Bb, nc, chunk, nh)
    Ci = Cc[:, :, :, None, :] * cume_c[..., None]         # (B,nc,cs,nh,N)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Ci, h_prev).astype(x.dtype)
    y = y_intra + y_inter.reshape(Bb, S, nh, hd)
    return y, st_s[:, -1]
