"""Flash prefill attention (causal + optional sliding window).

Used by the LazyBatching *catch-up* path: a request that joins late must
prefill its prompt quickly without materializing O(S²) scores. Standard
blockwise online-softmax flash attention, TPU-tiled:

  * grid = (B, H, S // block_q, T // block_k); the kv loop is the innermost
    (sequential) grid dim so (m, l, acc) scratch carries across it,
  * causal + window masking at block granularity — fully-masked kv blocks
    are skipped by zeroing contribution (mask computed positionwise),
  * all score/PV products are (block_q, D) x (D, block_k) MXU matmuls.

VMEM per step: q/k/v blocks (block_q·D + 2·block_k·D) + scratch
(block_q·(D+2)) f32 ≈ 0.7 MB at block 512, D=128.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, scale: float, q_offset: int,
            window: Optional[int], kv_len: int):
    i = pl.program_id(2)      # q block
    j = pl.program_id(3)      # kv block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (block_q, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_k, D)
    v = v_ref[0, 0].astype(jnp.float32)

    qpos = q_offset + i * block_q + jax.lax.iota(jnp.int32, block_q)
    kpos = j * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where(mask, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "window", "q_offset", "interpret"))
def flash_attention(q, k, v, *, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: bool | None = None):
    """q: (B, S, H, D); k, v: (B, T, H, D) — kv heads already repeated.
    Causal with ``q_offset`` (query i attends keys <= q_offset + i);
    optional sliding ``window``. Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    if S % block_q != 0 or T % block_k != 0:
        raise ValueError(
            f"flash_attention needs block-aligned sequence lengths: "
            f"S={S} %% block_q={block_q} and T={T} %% block_k={block_k} "
            f"must both be 0 — pad the sequence or pass matching blocks")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    scale = 1.0 / math.sqrt(D)

    # (B, H, S, D) layout so the matmul dims are minor
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               scale=scale, q_offset=q_offset, window=window,
                               kv_len=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, S // block_q, T // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
