"""Pure-jnp oracles for every kernel (the allclose references)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.ssm import ssd_chunked as _ssd_chunked_ref


def ragged_decode_attention_ref(q, k, v, lengths):
    """q: (B, H, D); k, v: (B, T, KV, D); lengths: (B,)."""
    B, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    kf = jnp.repeat(k, G, axis=2)            # (B, T, H, D)
    vf = jnp.repeat(v, G, axis=2)
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    valid = jnp.arange(T)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_ref(q, k, v, *, window: Optional[int] = None,
                        q_offset: int = 0):
    """q: (B, S, H, D); k, v: (B, T, H, D); causal w/ offset + window."""
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def fused_rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ssd_chunked_ref(x, dt, A, B_ssm, C_ssm, chunk: int):
    """The model's own pure-jnp SSD implementation is the oracle."""
    return _ssd_chunked_ref(x, dt, A, B_ssm, C_ssm, chunk)
