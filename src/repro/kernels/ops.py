"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU
(the target) — the same call sites work in both worlds. The model layers
call these through ``RuntimeFlags``-gated dispatch; the pure-jnp paths in
``repro.models.layers`` / ``repro.models.ssm`` remain the oracles.
"""
from __future__ import annotations

from .flash_attn import flash_attention
from .ragged_decode_attn import ragged_decode_attention
from .rmsnorm import fused_rmsnorm
from .ssd_chunk import ssd_chunk_intra, ssd_chunked_pallas

__all__ = [
    "flash_attention", "ragged_decode_attention", "fused_rmsnorm",
    "ssd_chunk_intra", "ssd_chunked_pallas",
]
