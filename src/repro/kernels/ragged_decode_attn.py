"""Ragged decode attention — the LazyBatching decode hot-spot.

Lazily merged sub-batches have *ragged* per-request progress: each request
joined the batch at a different time, so each row of the merged decode batch
attends over a different KV length. On GPU the paper's prototype replays
per-request kernels; the TPU-native adaptation (DESIGN.md §3) executes the
whole merged sub-batch as ONE kernel:

  * grid = (batch, T // block_t): each step consumes one KV block of one row,
  * per-row ``lengths`` (scalar-prefetched into SMEM) masks invalid
    positions; rows with short KV skip whole blocks via a cheap
    ``all-masked`` early-out on the accumulate,
  * online softmax (m, l, acc) carried in f32 VMEM scratch across KV blocks,
  * GQA: queries are processed per KV group, so every score/PV product is a
    plain (G, D) x (D, block_t) MXU matmul (no head-repeat
    materialization in HBM).

VMEM budget per step: q (H·D) + k,v blocks (2·block_t·KV·D) + scratch
(H·(D+2)) f32 — with block_t=512, KV=8, D=128, H=32 that is ~1.3 MB,
comfortably inside the ~16 MB/core VMEM of TPU v5e. MXU alignment: D and
block_t are multiples of 128 in production configs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(slot_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, block_t: int, scale: float):
    del slot_ref          # consumed by the BlockSpec index maps only
    b = pl.program_id(0)
    j = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (H, D)
    k = k_ref[0]                                   # (block_t, KV, D)
    v = v_ref[0]
    H, D = q.shape
    KV = k.shape[1]
    G = H // KV

    length = len_ref[b]
    tpos = j * block_t + jax.lax.iota(jnp.int32, block_t)
    valid = tpos < length                          # (block_t,)

    m_prev = m_ref[...]                            # (H, 1) f32
    l_prev = l_ref[...]
    acc_prev = acc_ref[...]                        # (H, D) f32

    scores = jnp.concatenate([
        jax.lax.dot_general(q[g * G:(g + 1) * G].astype(jnp.float32),
                            k[:, g, :].astype(jnp.float32),
                            (((1,), (1,)), ((), ())))      # (G, block_t)
        for g in range(KV)], axis=0) * scale
    scores = jnp.where(valid[None, :], scores, -1e30)

    m_cur = jnp.max(scores, axis=1, keepdims=True)          # (H, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(valid[None, :], jnp.exp(scores - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)

    pv = jnp.concatenate([
        jax.lax.dot_general(p[g * G:(g + 1) * G],
                            v[:, g, :].astype(jnp.float32),
                            (((1,), (0,)), ((), ())))       # (G, D)
        for g in range(KV)], axis=0)
    acc_new = acc_prev * corr + pv

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == nt - 1)
    def _done():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ragged_decode_attention(q, k, v, lengths, *, slots=None,
                            block_t: int = 512,
                            interpret: bool | None = None):
    """q: (B, H, D); k, v: (N, T, KV, D); lengths: (B,) int32 — row i attends
    to k[row_i, :lengths[i]]. Returns (B, H, D).

    ``slots`` ((B,) int32, optional) maps query row i to K/V arena row
    ``slots[i]`` — the zero-copy path for the serving engine's persistent
    slot arena (N = n_slots >= B): the scalar-prefetched slot vector drives
    the K/V BlockSpec index maps, so each grid step DMAs exactly the KV
    block of its request's slot and no (B, T, KV, D) gather is ever
    materialized. Without ``slots``, row i reads k[i] (N == B).
    """
    B, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    if H % KV != 0:
        raise ValueError(
            f"ragged_decode_attention: query heads H={H} must be a "
            f"multiple of kv heads KV={KV} (grouped-query repeat factor)")
    block_t = min(block_t, T)
    if T % block_t != 0:
        raise ValueError(
            f"ragged_decode_attention: kv length T={T} must be a "
            f"multiple of block_t={block_t} — pad the arena length or "
            f"pass a divisor block")
    if slots is None:
        slots = jnp.arange(B, dtype=jnp.int32)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_kernel, block_t=block_t, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T // block_t),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, slot, lens: (b, 0, 0)),
            pl.BlockSpec((1, block_t, KV, D),
                         lambda b, j, slot, lens: (slot[b], j, 0, 0)),
            pl.BlockSpec((1, block_t, KV, D),
                         lambda b, j, slot, lens: (slot[b], j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j, slot, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(slots, lengths, q, k, v)
