"""Fused RMSNorm kernel.

The residual-stream norm runs twice per layer on every token — a pure
memory-bound op. Fusing square/mean/rsqrt/scale into one VMEM pass reads
the activation exactly once (the jnp reference materializes the f32
upcast + variance as separate HBM round-trips when XLA fusion is defeated
by sharding boundaries).

grid = rows // block_rows; each step normalizes a (block_rows, D) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
                  interpret: bool | None = None):
    """x: (..., D); scale: (D,). Returns rmsnorm(x) * scale in x.dtype."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
