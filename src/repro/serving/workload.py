"""Served-workload descriptions: node graphs + request generation.

A workload is a template of *segments*; a request instantiates the template
with its sampled prompt / decode lengths into a linear node sequence
(paper §II-A: serialized node-wise execution; dynamic graphs unrolled).

Node ids are shared across unroll repetitions when weights are shared
(``cell`` nodes): RNN cells, transformer decode-cycle layers. The cost of a
node execution for one sample is

    flops(ctx)  = flops + flops_per_ctx · ctx
    bytes(ctx)  = act_bytes + bytes_per_ctx · ctx     (+ weight_bytes, batch-amortized)

where ctx is the sample's current context length (attention reads grow with
progress — the ragged-batch effect of lazily merged requests).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ModelConfig
from ..models import cost as C
from ..core.request import Request


# ---------------------------------------------------------------------------
# Length distributions (paper Fig. 11: WMT-2019 output-length characterization)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LengthDist:
    """Categorical distribution over integer lengths."""
    lengths: Tuple[int, ...]
    probs: Tuple[float, ...]

    def sample(self, rng: np.random.Generator) -> int:
        return int(rng.choice(self.lengths, p=self.probs))

    def quantile(self, q: float) -> int:
        acc = 0.0
        for l, p in zip(self.lengths, self.probs):
            acc += p
            if acc >= q:
                return l
        return self.lengths[-1]

    @property
    def mean(self) -> float:
        return float(np.dot(self.lengths, self.probs))


def wmt_like_length_dist(max_len: int = 80) -> LengthDist:
    """Synthetic mixture matched to the paper's Fig. 11 quantiles:
    ~70% of sentences <= 20 words, ~90% <= 30 words, tail to ``max_len``.
    """
    lengths = np.arange(1, max_len + 1)
    # lognormal-ish mass matched at the 70%/90% anchors (P[<=20]~0.74,
    # P[<=30]~0.90 — paper Fig. 11)
    mu, sigma = math.log(13.5), 0.62
    pdf = np.exp(-((np.log(lengths) - mu) ** 2) / (2 * sigma ** 2)) / lengths
    probs = pdf / pdf.sum()
    return LengthDist(tuple(int(l) for l in lengths), tuple(float(p) for p in probs))


def fixed_length(n: int) -> LengthDist:
    return LengthDist((n,), (1.0,))


# ---------------------------------------------------------------------------
# Node / workload descriptions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NodeDesc:
    node_id: str
    flops: float
    weight_bytes: float
    act_bytes: float
    flops_per_ctx: float = 0.0
    bytes_per_ctx: float = 0.0
    m_rows: int = 1          # systolic rows contributed per sample (MXU fill)
    cell: bool = False       # weight-shared across unroll steps
    # execution metadata for real-engine dispatch (set by from_model_config;
    # empty for the analytic paper workloads, which are never engine-served):
    phase: str = ""          # "emb" | "prefill" | "decode" | "head"
    layer: int = -1          # model layer index for prefill/decode nodes

    def sample_flops(self, ctx: int) -> float:
        return self.flops + self.flops_per_ctx * ctx

    def sample_bytes(self, ctx: int) -> float:
        return self.act_bytes + self.bytes_per_ctx * ctx


@dataclass(frozen=True)
class Segment:
    ids: Tuple[str, ...]
    repeat: str = "once"      # "once" | "prompt" | "decode"


@dataclass
class Workload:
    name: str
    nodes: Dict[str, NodeDesc]
    segments: List[Segment]
    prompt_dist: Optional[LengthDist] = None
    decode_dist: Optional[LengthDist] = None
    kind: str = "static"      # static | seq2seq | autoregressive

    # ------------------------------------------------------------------
    def sample_request(self, rng: np.random.Generator, arrival: float) -> Request:
        p = self.prompt_dist.sample(rng) if self.prompt_dist else 0
        d = self.decode_dist.sample(rng) if self.decode_dist else 0
        seq, prefix_len, cycle_len = self.build_sequence(p, d)
        req = Request(workload=self, arrival=arrival, sequence=seq)
        req.prompt_len = p
        req.decode_len = d
        req.prefix_len = prefix_len
        req.cycle_len = cycle_len
        return req

    def build_sequence(self, prompt_len: int, decode_len: int):
        seq: List[Tuple[str, int]] = []
        cycle_len = 0
        prefix_len = 0
        for seg in self.segments:
            if seg.repeat == "once":
                seq.extend((nid, prompt_len) for nid in seg.ids)
            elif seg.repeat == "prompt":
                for t in range(prompt_len):
                    seq.extend((nid, t + 1) for nid in seg.ids)
            elif seg.repeat == "decode":
                cycle_len = len(seg.ids)
                prefix_len = len(seq)
                for t in range(decode_len):
                    seq.extend((nid, prompt_len + t + 1) for nid in seg.ids)
            else:
                raise ValueError(seg.repeat)
        if cycle_len == 0:
            prefix_len = len(seq)
        return seq, prefix_len, cycle_len

    # ------------------------------------------------------------------
    def cycle_ids(self) -> Tuple[str, ...]:
        for seg in self.segments:
            if seg.repeat == "decode":
                return seg.ids
        return ()

    def cycle_end_id(self) -> Optional[str]:
        """Last node id of the decode cycle — the natural run-commit
        boundary for iteration-level scheduling (None for static graphs,
        which keep single-node commits)."""
        cyc = self.cycle_ids()
        return cyc[-1] if cyc else None

    def commit_boundaries(self) -> frozenset:
        """Segment-final node ids: the points where preemptive policies end
        a committed run so admission/preemption/merging are re-evaluated at
        least once per segment (prefill) and per decode cycle. Memoized —
        it is consulted on every scheduling decision."""
        b = getattr(self, "_commit_boundaries", None)
        if b is None:
            b = frozenset(seg.ids[-1] for seg in self.segments)
            self._commit_boundaries = b
        return b

    def predicted_remaining_nodes(self, req: Request, dec_timesteps: int):
        """Conservative remaining node iterator for the slack model
        (Algorithm 1): true remaining prefix + ``dec_timesteps``-capped decode
        cycles. The *actual* decode length is never consulted — only the
        profile-driven dec_timesteps overprovision (paper §IV-C).
        """
        cyc = self.cycle_ids()
        if not cyc:
            yield from req.sequence[req.idx:]
            return
        prefix_len, cycle_len = req.prefix_len, req.cycle_len
        prompt = getattr(req, "prompt_len", 0)
        if req.idx < prefix_len:
            yield from req.sequence[req.idx:prefix_len]
            done_cycles, in_cycle = 0, 0
        else:
            done_cycles, in_cycle = divmod(req.idx - prefix_len, cycle_len)
            # finish the current cycle
            for j in range(in_cycle, cycle_len):
                yield (cyc[j], prompt + done_cycles + 1)
            done_cycles += 1
        remaining = max(dec_timesteps - done_cycles, 1 if not req.done else 0)
        for t in range(remaining):
            for nid in cyc:
                yield (nid, prompt + done_cycles + t + 1)


# ---------------------------------------------------------------------------
# Paper workloads (Table II + §VI-C): ResNet, GNMT, Transformer, VGG,
# MobileNet, LAS, BERT
# ---------------------------------------------------------------------------

def _conv_node(nid, cin, cout, k, h, w, stride=1, dtype=2) -> NodeDesc:
    ho, wo = h // stride, w // stride
    flops = 2 * ho * wo * cout * cin * k * k
    weights = cin * cout * k * k * dtype
    act = (h * w * cin + ho * wo * cout) * dtype
    return NodeDesc(nid, flops, weights, act, m_rows=ho * wo)


def _fc_node(nid, cin, cout, dtype=2, cell=False) -> NodeDesc:
    return NodeDesc(nid, 2 * cin * cout, cin * cout * dtype,
                    (cin + cout) * dtype, m_rows=1, cell=cell)


def resnet50() -> Workload:
    nodes, order = {}, []

    def add(nd):
        nodes[nd.node_id] = nd
        order.append(nd.node_id)

    add(_conv_node("conv1", 3, 64, 7, 224, 224, stride=2))
    stages = [(64, 256, 3, 56), (256, 512, 4, 28), (512, 1024, 6, 14),
              (1024, 2048, 3, 7)]
    cin = 64
    for si, (mid_in, cout, blocks, hw) in enumerate(stages):
        mid = cout // 4
        for b in range(blocks):
            pre = f"s{si}b{b}"
            add(_conv_node(pre + "_c1", cin, mid, 1, hw, hw))
            add(_conv_node(pre + "_c2", mid, mid, 3, hw, hw))
            add(_conv_node(pre + "_c3", mid, cout, 1, hw, hw))
            cin = cout
    add(_fc_node("fc", 2048, 1000))
    return Workload("resnet", nodes, [Segment(tuple(order))], kind="static")


def vgg16() -> Workload:
    nodes, order = {}, []
    spec = [(3, 64, 224), (64, 64, 224), (64, 128, 112), (128, 128, 112),
            (128, 256, 56), (256, 256, 56), (256, 256, 56),
            (256, 512, 28), (512, 512, 28), (512, 512, 28),
            (512, 512, 14), (512, 512, 14), (512, 512, 14)]
    for i, (cin, cout, hw) in enumerate(spec):
        nd = _conv_node(f"conv{i}", cin, cout, 3, hw, hw)
        nodes[nd.node_id] = nd
        order.append(nd.node_id)
    for i, (cin, cout) in enumerate([(25088, 4096), (4096, 4096), (4096, 1000)]):
        nd = _fc_node(f"fc{i}", cin, cout)
        nodes[nd.node_id] = nd
        order.append(nd.node_id)
    return Workload("vggnet", nodes, [Segment(tuple(order))], kind="static")


def mobilenet_v1() -> Workload:
    nodes, order = {}, []

    def add(nd):
        nodes[nd.node_id] = nd
        order.append(nd.node_id)

    add(_conv_node("conv0", 3, 32, 3, 224, 224, stride=2))
    spec = [(32, 64, 112, 1), (64, 128, 112, 2), (128, 128, 56, 1),
            (128, 256, 56, 2), (256, 256, 28, 1), (256, 512, 28, 2)] + \
           [(512, 512, 14, 1)] * 5 + [(512, 1024, 14, 2), (1024, 1024, 7, 1)]
    for i, (cin, cout, hw, s) in enumerate(spec):
        ho = hw // s
        dw = NodeDesc(f"dw{i}", 2 * ho * ho * cin * 9, cin * 9 * 2,
                      (hw * hw + ho * ho) * cin * 2, m_rows=ho * ho)
        add(dw)
        add(_conv_node(f"pw{i}", cin, cout, 1, ho, ho))
    add(_fc_node("fc", 1024, 1000))
    return Workload("mobilenet", nodes, [Segment(tuple(order))], kind="static")


def _lstm_cell(nid, d, dtype=2) -> NodeDesc:
    # 4 gates, input + hidden matmuls
    flops = 2 * 4 * d * (2 * d)
    weights = 4 * d * 2 * d * dtype
    return NodeDesc(nid, flops, weights, 4 * d * dtype, m_rows=1, cell=True)


def gnmt(max_len: int = 80) -> Workload:
    """8-layer LSTM seq2seq with attention (GNMT [6]), d=1024.

    Encoder layers run time-unrolled with *stationary weights* (weights are
    loaded once per layer and all prompt timesteps stream through), so each
    encoder layer is ONE node whose cost scales with the prompt length.
    Decoder cells reload weights every output step (the output token feeds
    back through all layers) — one cell node per layer per step.
    """
    d, vocab = 1024, 32000
    cell_flops = 2 * 4 * d * 2 * d
    cell_weights = 4 * d * 2 * d * 2
    nodes: Dict[str, NodeDesc] = {}
    enc = []
    for i in range(8):
        nd = NodeDesc(f"enc{i}", 0.0, cell_weights, d * 2,
                      flops_per_ctx=cell_flops, bytes_per_ctx=4 * d * 2,
                      m_rows=16)
        nodes[nd.node_id] = nd
        enc.append(nd.node_id)
    dec = []
    for i in range(8):
        nd = _lstm_cell(f"dec{i}", d)
        nodes[nd.node_id] = nd
        dec.append(nd.node_id)
    att = NodeDesc("att", 0.0, d * d * 2, d * 2, flops_per_ctx=2 * 2 * d,
                   bytes_per_ctx=d * 2, cell=True)
    nodes["att"] = att
    head = _fc_node("head", d, vocab, cell=True)
    nodes["head"] = head
    emb = NodeDesc("emb", 0.0, d * 2, d * 2)
    nodes["emb"] = emb
    dist = wmt_like_length_dist(max_len)
    return Workload(
        "gnmt", nodes,
        [Segment(("emb",) + tuple(enc)),
         Segment(tuple(dec) + ("att", "head"), repeat="decode")],
        prompt_dist=dist, decode_dist=dist, kind="seq2seq")


def transformer(max_len: int = 80) -> Workload:
    """Transformer-base, 6 enc + 6 dec, d=512, ff=2048 (MLPerf)."""
    d, ff, vocab = 512, 2048, 32000
    nodes: Dict[str, NodeDesc] = {}
    enc_ids = []
    for i in range(6):
        # full-sequence encoder layer: costs scale with prompt ctx
        per_tok = 2 * d * (4 * d + 2 * ff)
        nd = NodeDesc(f"enc{i}", 0.0, (4 * d * d + 2 * d * ff) * 2,
                      d * 2, flops_per_ctx=per_tok, bytes_per_ctx=2 * d * 2,
                      m_rows=16)
        nodes[nd.node_id] = nd
        enc_ids.append(nd.node_id)
    dec_ids = []
    for i in range(6):
        per_step = 2 * d * (4 * d + 2 * d + 2 * ff)     # self + cross proj + ffn
        nd = NodeDesc(f"dec{i}", per_step, (6 * d * d + 2 * d * ff) * 2,
                      2 * d * 2, flops_per_ctx=2 * 2 * d,
                      bytes_per_ctx=2 * d * 2, cell=True)
        nodes[nd.node_id] = nd
        dec_ids.append(nd.node_id)
    head = _fc_node("head", d, vocab, cell=True)
    nodes["head"] = head
    emb = NodeDesc("emb", 0.0, d * 2, d * 2)
    nodes["emb"] = emb
    dist = wmt_like_length_dist(max_len)
    return Workload(
        "transformer", nodes,
        [Segment(("emb",) + tuple(enc_ids)),
         Segment(tuple(dec_ids) + ("head",), repeat="decode")],
        prompt_dist=dist, decode_dist=dist, kind="seq2seq")


def las() -> Workload:
    """Listen-Attend-and-Spell: 3-layer pyramidal BiLSTM encoder + 2-layer
    attention decoder (d=512)."""
    d = 512
    nodes: Dict[str, NodeDesc] = {}
    enc_ids = []
    for i in range(3):
        nd = NodeDesc(f"enc{i}", 0.0, 2 * 4 * d * 2 * d * 2, d * 2,
                      flops_per_ctx=2 * 2 * 4 * d * 2 * d / (2 ** i),
                      m_rows=8)
        nodes[nd.node_id] = nd
        enc_ids.append(nd.node_id)
    dec_ids = []
    for i in range(2):
        nd = _lstm_cell(f"dec{i}", d)
        nodes[nd.node_id] = nd
        dec_ids.append(nd.node_id)
    att = NodeDesc("att", 0.0, d * d * 2, d * 2, flops_per_ctx=2 * 2 * d,
                   bytes_per_ctx=d * 2, cell=True)
    nodes["att"] = att
    head = _fc_node("head", d, 10000, cell=True)
    nodes["head"] = head
    frames = LengthDist(tuple(range(100, 500, 50)), (0.125,) * 8)
    chars = LengthDist(tuple(range(10, 81, 10)), (0.125,) * 8)
    return Workload(
        "las", nodes,
        [Segment(tuple(enc_ids))] +
        [Segment(tuple(dec_ids) + ("att", "head"), repeat="decode")],
        prompt_dist=frames, decode_dist=chars, kind="seq2seq")


def bert_base(seq: int = 128) -> Workload:
    d, ff = 768, 3072
    nodes: Dict[str, NodeDesc] = {}
    ids = []
    for i in range(12):
        per_tok = 2 * d * (4 * d + 2 * ff) + 2 * 2 * d * seq
        nd = NodeDesc(f"enc{i}", per_tok * seq, (4 * d * d + 2 * d * ff) * 2,
                      seq * d * 2 * 2, m_rows=seq)
        nodes[nd.node_id] = nd
        ids.append(nd.node_id)
    head = _fc_node("head", d, 2)
    nodes["head"] = head
    return Workload("bert", nodes, [Segment(tuple(ids) + ("head",))],
                    kind="static")


# ---------------------------------------------------------------------------
# Assigned-architecture adapters: ModelConfig -> served Workload
# ---------------------------------------------------------------------------

def from_model_config(cfg: ModelConfig, *, prompt_dist: LengthDist = None,
                      decode_dist: LengthDist = None,
                      dtype_bytes: int = 2) -> Workload:
    """Expose one of the 10 assigned architectures as a servable workload
    (LazyBatching as a first-class feature across every arch family)."""
    prompt_dist = prompt_dist or fixed_length(128)
    decode_dist = decode_dist or wmt_like_length_dist(64)
    nodes: Dict[str, NodeDesc] = {}

    d = cfg.d_model
    emb = NodeDesc("emb", 0.0, d * dtype_bytes * 64, d * dtype_bytes,
                   phase="emb")
    nodes["emb"] = emb

    kinds = C._layer_kinds(cfg)
    prefill_ids, decode_ids = [], []
    typical_prompt = prompt_dist.quantile(0.5)
    for i, kind in enumerate(kinds):
        k = "dense" if kind == "attn" else kind
        win = cfg.hybrid.local_window if (cfg.hybrid and kind == "attn") else None
        # prefill node: whole prompt in one pass -> per-ctx coefficients
        c1 = C.block_cost(cfg, k, 1, 1, 1, window=win, dtype_bytes=dtype_bytes)
        c2 = C.block_cost(cfg, k, 1, 1, 2, window=win, dtype_bytes=dtype_bytes)
        dflops = c2.flops - c1.flops            # per-ctx growth at decode
        dbytes = c2.act_bytes - c1.act_bytes
        pid = f"P{i}"
        per_tok = C.block_cost(cfg, k, 1, typical_prompt, typical_prompt,
                               window=win, dtype_bytes=dtype_bytes)
        nodes[pid] = NodeDesc(
            pid, 0.0, per_tok.weight_bytes, d * dtype_bytes,
            flops_per_ctx=per_tok.flops / typical_prompt,
            bytes_per_ctx=per_tok.act_bytes / typical_prompt,
            m_rows=8, cell=True, phase="prefill", layer=i)
        prefill_ids.append(pid)
        did = f"D{i}"
        nodes[did] = NodeDesc(
            did, c1.flops - dflops, c1.weight_bytes,
            c1.act_bytes - dbytes, flops_per_ctx=dflops,
            bytes_per_ctx=dbytes, m_rows=1, cell=True,
            phase="decode", layer=i)
        decode_ids.append(did)
    head = NodeDesc("head", 2 * d * cfg.vocab_size,
                    d * cfg.vocab_size * dtype_bytes,
                    (d + cfg.vocab_size) * dtype_bytes, cell=True,
                    phase="head")
    nodes["head"] = head
    return Workload(
    # prefill executes once over the whole prompt (chunked internally)
        cfg.name, nodes,
        [Segment(("emb",) + tuple(prefill_ids)),
         Segment(tuple(decode_ids) + ("head",), repeat="decode")],
        prompt_dist=prompt_dist, decode_dist=decode_dist,
        kind="autoregressive")


PAPER_WORKLOADS = {
    "resnet": resnet50,
    "gnmt": gnmt,
    "transformer": transformer,
    "vggnet": vgg16,
    "mobilenet": mobilenet_v1,
    "las": las,
    "bert": bert_base,
}


def get_workload(name: str) -> Workload:
    if name in PAPER_WORKLOADS:
        return PAPER_WORKLOADS[name]()
    from ..configs import ARCHITECTURES
    if name in ARCHITECTURES:
        return from_model_config(ARCHITECTURES[name])
    raise KeyError(f"unknown workload {name!r}")
