"""Discrete-event inference server (paper Fig. 9 serving architecture).

One backend processor executes one (sub-)batched *node* at a time; the
scheduler (policy) is consulted at every node boundary and on arrivals when
idle — exactly the node-level execution model the paper builds on. The
executor is pluggable:

  * ``SimExecutor``  — analytical NPU latency model (paper's methodology),
  * the real-JAX engine in ``repro.serving.engine`` implements the same
    interface and measures wall-clock node latencies on device.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import List, Optional, Sequence

from ..core.policies import Policy
from ..core.request import Request, SubBatch
from .metrics import ServeStats
from .npu_model import NPUPerfModel
from .traffic import Trace
from .workload import NodeDesc


class Executor:
    def execute(self, sb: SubBatch, node_id: str) -> float:
        """Execute one node for a sub-batch; returns latency in seconds."""
        raise NotImplementedError

    def on_finished(self, reqs: Sequence[Request]) -> None:
        """Completion hook: the server calls this with every request that
        finished at the last node boundary, so stateful executors can
        release per-request resources (e.g. KV-cache arena slots). The
        analytic simulator keeps no per-request state — default no-op."""


class SimExecutor(Executor):
    def __init__(self, perf_model: NPUPerfModel):
        self.perf = perf_model

    def execute(self, sb: SubBatch, node_id: str) -> float:
        reqs = sb.live_requests
        wl = reqs[0].workload
        node = wl.nodes[node_id]
        ctxs = [r.next_ctx for r in reqs]
        return self.perf.node_latency(node, ctxs)


@dataclass
class ServerLog:
    nodes_executed: int = 0
    busy_time: float = 0.0
    batch_size_sum: int = 0

    @property
    def avg_batch_size(self) -> float:
        return self.batch_size_sum / max(1, self.nodes_executed)


class InferenceServer:
    def __init__(self, policy: Policy, executor: Executor):
        self.policy = policy
        self.executor = executor
        self.log = ServerLog()

    def run(self, trace: Trace, *, drain: bool = True) -> ServeStats:
        """Run the trace to completion; returns serving statistics."""
        arrivals = sorted(trace.requests, key=lambda r: r.arrival)
        ai = 0
        now = 0.0
        finished: List[Request] = []
        stats = ServeStats(policy=self.policy.name, duration=trace.duration)

        while True:
            # admit all arrivals up to `now`
            while ai < len(arrivals) and arrivals[ai].arrival <= now + 1e-12:
                self.policy.enqueue(arrivals[ai], now)
                ai += 1

            work = self.policy.next_work(now)
            if work is None:
                # idle: jump to the next arrival or policy timer
                candidates = []
                if ai < len(arrivals):
                    candidates.append(arrivals[ai].arrival)
                t = self.policy.next_timer(now)
                if t is not None:
                    candidates.append(max(t, now))
                if not candidates:
                    break                       # fully drained
                now = min(candidates)
                continue

            sb, node_id = work
            latency = self.executor.execute(sb, node_id)
            self.log.nodes_executed += 1
            self.log.busy_time += latency
            self.log.batch_size_sum += sb.size
            now += latency
            done_now = self.policy.work_done(sb, now)
            if done_now:
                self.executor.on_finished(done_now)
            finished.extend(done_now)
            if not drain and now > trace.duration and ai >= len(arrivals):
                break

        stats.finished = finished
        return stats


def run_policy(policy: Policy, trace: Trace,
               perf_model: Optional[NPUPerfModel] = None) -> ServeStats:
    perf_model = perf_model or NPUPerfModel()
    server = InferenceServer(policy, SimExecutor(perf_model))
    return server.run(trace.fresh())
