"""Discrete-event inference serving (paper Fig. 9 serving architecture).

One backend processor executes one committed *run* of consecutive nodes at
a time for one (sub-)batch; the scheduler (policy) is consulted at every
run boundary and on arrivals when idle. Policies commit exactly the span
to their next possible merge/preemption point (see ``core.policies``), so
scheduling stays node-granular where it matters while the executor is free
to fuse a whole run into one device dispatch.

The loop itself lives in :class:`~repro.serving.session.ServingSession`
(the online submit/stream front-end); this module keeps the offline
conveniences on top of it:

  * ``SimExecutor``  — analytical NPU latency model (paper's methodology);
    model-agnostic — it reads each request's own workload, so one
    instance serves every registered model of a multi-tenant session,
  * ``InferenceServer`` / ``run_policy`` — trace-in, stats-out wrappers
    (each run is one drained session; behavior and statistics unchanged).

``Executor`` — the pre-session alias of the :class:`~repro.serving.
backend.Backend` contract — is retired; accessing it here still resolves
to ``Backend`` behind a ``DeprecationWarning``.
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..core.policies import Policy
from .backend import Backend, NodeLat, ServerLog, run_label
from .metrics import ServeStats
from .npu_model import NPUPerfModel
from .session import run_trace
from .traffic import Trace


def __getattr__(name):
    if name == "Executor":          # retired alias: warn once per call site
        warnings.warn("Executor is deprecated; use "
                      "repro.serving.backend.Backend",
                      DeprecationWarning, stacklevel=2)
        return Backend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class SimExecutor(Backend):
    def __init__(self, perf_model: NPUPerfModel):
        self.perf = perf_model

    def execute(self, model, sb, node_id: str) -> float:
        reqs = sb.live_requests
        wl = reqs[0].workload
        node = wl.nodes[node_id]
        ctxs = [r.next_ctx for r in reqs]
        return self.perf.node_latency(node, ctxs)

    def execute_run(self, model, sb, node_ids):
        # per-node ctx is read at the node's own offset into each member's
        # sequence (requests only advance at run boundaries, but attention
        # context still grows per node *within* the run)
        reqs = sb.live_requests
        wl = reqs[0].workload
        lats = []
        for k, nid in enumerate(node_ids):
            ctxs = [r.sequence[r.idx + k][1] for r in reqs]
            lats.append(self.perf.node_latency(wl.nodes[nid], ctxs))
        return sum(lats), lats


class InferenceServer:
    """Offline wrapper: one drained :class:`ServingSession` per ``run``."""

    def __init__(self, policy: Policy, executor: Backend):
        self.policy = policy
        self.executor = executor
        self.log = ServerLog()

    def run(self, trace: Trace, *, drain: bool = True) -> ServeStats:
        """Run the trace to completion; returns serving statistics."""
        return run_trace(self.policy, self.executor, trace, drain=drain,
                         log=self.log)


def run_policy(policy: Policy, trace: Trace,
               perf_model: Optional[NPUPerfModel] = None) -> ServeStats:
    perf_model = perf_model or NPUPerfModel()
    server = InferenceServer(policy, SimExecutor(perf_model))
    return server.run(trace.fresh())
