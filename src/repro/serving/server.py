"""Discrete-event inference server (paper Fig. 9 serving architecture).

One backend processor executes one committed *run* of consecutive nodes at
a time for one (sub-)batch; the scheduler (policy) is consulted at every
run boundary and on arrivals when idle. Policies commit exactly the span
to their next possible merge/preemption point (see ``core.policies``), so
scheduling stays node-granular where it matters while the executor is free
to fuse a whole run into one device dispatch. The executor is pluggable:

  * ``SimExecutor``  — analytical NPU latency model (paper's methodology),
  * the real-JAX engine in ``repro.serving.engine`` implements the same
    interface; it fuses committed decode runs into single scanned
    dispatches and measures *run* (not per-node) wall-clock latency.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.policies import Policy
from ..core.request import Request, SubBatch
from .metrics import ServeStats
from .npu_model import NPUPerfModel
from .traffic import Trace


class Executor:
    def execute(self, sb: SubBatch, node_id: str) -> float:
        """Execute one node for a sub-batch; returns latency in seconds."""
        raise NotImplementedError

    def execute_run(self, sb: SubBatch,
                    node_ids: Sequence[str]) -> Tuple[float, Optional[List[float]]]:
        """Execute a committed run of consecutive nodes for one sub-batch.

        Returns ``(total_latency, per_node_latencies)``. Executors that
        fuse the run into fewer device dispatches than nodes return
        ``(total, None)`` — per-node latency is unobservable inside a fused
        dispatch, and the server clock only needs run latency (sync points
        live at scheduler-visible run boundaries). The default loops
        :meth:`execute` per node, the degenerate single-dispatch-per-node
        behavior.
        """
        lats = [self.execute(sb, nid) for nid in node_ids]
        return sum(lats), lats

    def on_finished(self, reqs: Sequence[Request]) -> None:
        """Completion hook: the server calls this with every request that
        finished at the last run boundary, so stateful executors can
        release per-request resources (e.g. KV-cache arena slots). The
        analytic simulator keeps no per-request state — default no-op."""


class SimExecutor(Executor):
    def __init__(self, perf_model: NPUPerfModel):
        self.perf = perf_model

    def execute(self, sb: SubBatch, node_id: str) -> float:
        reqs = sb.live_requests
        wl = reqs[0].workload
        node = wl.nodes[node_id]
        ctxs = [r.next_ctx for r in reqs]
        return self.perf.node_latency(node, ctxs)

    def execute_run(self, sb, node_ids):
        # per-node ctx is read at the node's own offset into each member's
        # sequence (requests only advance at run boundaries, but attention
        # context still grows per node *within* the run)
        reqs = sb.live_requests
        wl = reqs[0].workload
        lats = []
        for k, nid in enumerate(node_ids):
            ctxs = [r.sequence[r.idx + k][1] for r in reqs]
            lats.append(self.perf.node_latency(wl.nodes[nid], ctxs))
        return sum(lats), lats


@dataclass
class NodeLat:
    """Per-node-id (or per-fused-run-span) latency accumulator."""
    count: int = 0
    total: float = 0.0

    @property
    def mean(self) -> float:
        return self.total / max(1, self.count)


@dataclass
class ServerLog:
    nodes_executed: int = 0
    runs_executed: int = 0
    busy_time: float = 0.0
    batch_size_sum: int = 0
    # per-node-id latency breakdown; fused runs (no per-node observability)
    # are keyed by their span, e.g. "D0..head" — making run-fusion wins
    # visible per phase next to the per-node entries
    node_lat: Dict[str, NodeLat] = field(default_factory=dict)

    def record(self, key: str, latency: float, n: int = 1):
        ent = self.node_lat.setdefault(key, NodeLat())
        ent.count += n
        ent.total += latency

    @property
    def avg_batch_size(self) -> float:
        return self.batch_size_sum / max(1, self.nodes_executed)

    @property
    def avg_run_length(self) -> float:
        return self.nodes_executed / max(1, self.runs_executed)


def run_label(node_ids: Sequence[str]) -> str:
    return (node_ids[0] if len(node_ids) == 1
            else f"{node_ids[0]}..{node_ids[-1]}")


class InferenceServer:
    def __init__(self, policy: Policy, executor: Executor):
        self.policy = policy
        self.executor = executor
        self.log = ServerLog()

    def run(self, trace: Trace, *, drain: bool = True) -> ServeStats:
        """Run the trace to completion; returns serving statistics."""
        arrivals = sorted(trace.requests, key=lambda r: r.arrival)
        ai = 0
        now = 0.0
        finished: List[Request] = []
        stats = ServeStats(policy=self.policy.name, duration=trace.duration)

        while True:
            # admit all arrivals up to `now`
            while ai < len(arrivals) and arrivals[ai].arrival <= now + 1e-12:
                self.policy.enqueue(arrivals[ai], now)
                ai += 1

            work = self.policy.next_work(now)
            if work is None:
                # idle: jump to the next arrival or policy timer
                candidates = []
                if ai < len(arrivals):
                    candidates.append(arrivals[ai].arrival)
                t = self.policy.next_timer(now)
                if t is not None:
                    candidates.append(max(t, now))
                if not candidates:
                    break                       # fully drained
                now = min(candidates)
                continue

            sb, run = work
            latency, per_node = self.executor.execute_run(sb, run)
            self.log.nodes_executed += len(run)
            self.log.runs_executed += 1
            self.log.busy_time += latency
            self.log.batch_size_sum += sb.size * len(run)
            if per_node is not None:
                for nid, lat in zip(run, per_node):
                    self.log.record(nid, lat)
            else:
                self.log.record(run_label(run), latency, n=len(run))
            now += latency
            done_now = self.policy.work_done(sb, now, len(run))
            if done_now:
                self.executor.on_finished(done_now)
            finished.extend(done_now)
            if not drain and now > trace.duration and ai >= len(arrivals):
                break

        stats.finished = finished
        return stats


def run_policy(policy: Policy, trace: Trace,
               perf_model: Optional[NPUPerfModel] = None) -> ServeStats:
    perf_model = perf_model or NPUPerfModel()
    server = InferenceServer(policy, SimExecutor(perf_model))
    return server.run(trace.fresh())
