"""Discrete-event inference serving (paper Fig. 9 serving architecture).

One backend processor executes one committed *run* of consecutive nodes at
a time for one (sub-)batch; the scheduler (policy) is consulted at every
run boundary and on arrivals when idle. Policies commit exactly the span
to their next possible merge/preemption point (see ``core.policies``), so
scheduling stays node-granular where it matters while the executor is free
to fuse a whole run into one device dispatch.

The loop itself lives in :class:`~repro.serving.session.ServingSession`
(the online submit/stream front-end); this module keeps the offline
conveniences on top of it:

  * ``SimExecutor``  — analytical NPU latency model (paper's methodology);
    model-agnostic — it reads each request's own workload, so one
    instance serves every registered model of a multi-tenant session,
  * ``InferenceServer`` / ``run_policy`` — trace-in, stats-out wrappers
    (each run is one drained session; behavior and statistics unchanged).

``Executor`` — the pre-session alias of the :class:`~repro.serving.
backend.Backend` contract — is retired; accessing it here still resolves
to ``Backend`` behind a ``DeprecationWarning``.
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..core.policies import Policy
from .backend import Backend, NodeLat, ServerLog, run_label
from .metrics import ServeStats
from .npu_model import NPUPerfModel
from .session import run_trace
from .traffic import Trace


def __getattr__(name):
    if name == "Executor":          # retired alias: warn once per call site
        warnings.warn("Executor is deprecated; use "
                      "repro.serving.backend.Backend",
                      DeprecationWarning, stacklevel=2)
        return Backend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class SimExecutor(Backend):
    """Analytic backend; optionally memory-bounded.

    ``max_slots`` models a device whose KV arena holds at most that many
    concurrently resident requests (one *slot* per live request, held
    from its first dispatched node until completion — the same lifetime
    the JAX engine's arena slots have). The simulator never refuses work:
    when the live set oversubscribes the cap, every dispatched node pays
    a linear thrash factor ``live / max_slots`` (the oversubscribed
    fraction of resident context must be re-staged over the host link
    each dispatch — the cost a memory-blind policy silently eats and a
    memory-aware one avoids by deferring admission). ``max_slots=None``
    (default) keeps the seed's unbounded behavior bit-identically.

    Per-request KV bytes are estimated analytically from the workload's
    node byte model (max context seen per node id × ``bytes_per_ctx``),
    feeding ``memory_stats()``'s per-model accounting.
    """

    def __init__(self, perf_model: NPUPerfModel,
                 max_slots: Optional[int] = None):
        self.perf = perf_model
        self.max_slots = max_slots
        # model -> {rid: kv_bytes}: requests seen executing, not yet finished
        self._live: dict = {}

    # ------------------------------------------------------------------
    @staticmethod
    def _kv_bytes(req) -> float:
        """Analytic per-request KV footprint: peak context per node id
        through the node byte model (weights are batch-amortized and
        excluded — this is the per-slot resident state)."""
        peak: dict = {}
        for nid, ctx in req.sequence:
            if ctx > peak.get(nid, -1):
                peak[nid] = ctx
        nodes = req.workload.nodes
        return float(sum(nodes[nid].bytes_per_ctx * c
                         for nid, c in peak.items()))

    def _touch(self, model, reqs):
        """Mark ``reqs`` live (slot held) and return the thrash factor."""
        live = self._live.setdefault(model, {})
        for r in reqs:
            if r.rid not in live:
                live[r.rid] = self._kv_bytes(r)
        if self.max_slots is None:
            return 1.0
        total = sum(len(per) for per in self._live.values())
        return max(1.0, total / self.max_slots)

    def on_finished(self, model, reqs):
        live = self._live.get(model)
        if live:
            for r in reqs:
                live.pop(r.rid, None)

    def reset_request(self, model, req):
        """Fault recovery: drop the request's simulated KV residency (its
        slot) — idempotent; a retry re-acquires via ``_touch`` on its
        next dispatch, exactly like a fresh admission."""
        live = self._live.get(model)
        if live:
            live.pop(req.rid, None)

    def release_request(self, model, req):
        """Forget the request entirely (``ServingSession.release``): the
        reset/release pair the Backend contract expects must BOTH exist on
        any backend that tracks per-request residency — releasing a
        terminal request whose residency was never dropped (e.g. a handle
        released without a drain) would otherwise leave a phantom slot
        inflating the thrash factor forever. Idempotent, like reset."""
        self.reset_request(model, req)

    def memory_stats(self, model=None):
        from .backend import MemoryStats
        n_live = sum(len(per) for per in self._live.values())
        n_mine = (n_live if model is None
                  else len(self._live.get(model, ())))
        total = self.max_slots if self.max_slots is not None else n_live
        return MemoryStats(
            slots_total=total,
            slots_live=n_mine,
            slots_free=max(0, total - n_live),
            bytes_resident=int(sum(b for per in self._live.values()
                                   for b in per.values())),
            bytes_per_slot=0.0,
            max_slots=self.max_slots,
            pool=id(self))

    # ------------------------------------------------------------------
    def execute(self, model, sb, node_id: str) -> float:
        reqs = sb.live_requests
        wl = reqs[0].workload
        node = wl.nodes[node_id]
        ctxs = [r.next_ctx for r in reqs]
        return self.perf.node_latency(node, ctxs) * self._touch(model, reqs)

    def execute_run(self, model, sb, node_ids):
        # per-node ctx is read at the node's own offset into each member's
        # sequence (requests only advance at run boundaries, but attention
        # context still grows per node *within* the run)
        reqs = sb.live_requests
        wl = reqs[0].workload
        thrash = self._touch(model, reqs)
        lats = []
        for k, nid in enumerate(node_ids):
            ctxs = [r.sequence[r.idx + k][1] for r in reqs]
            lats.append(self.perf.node_latency(wl.nodes[nid], ctxs) * thrash)
        return sum(lats), lats


class InferenceServer:
    """Offline wrapper: one drained :class:`ServingSession` per ``run``."""

    def __init__(self, policy: Policy, executor: Backend):
        self.policy = policy
        self.executor = executor
        self.log = ServerLog()

    def run(self, trace: Trace, *, drain: bool = True) -> ServeStats:
        """Run the trace to completion; returns serving statistics."""
        return run_trace(self.policy, self.executor, trace, drain=drain,
                         log=self.log)


def run_policy(policy: Policy, trace: Trace,
               perf_model: Optional[NPUPerfModel] = None) -> ServeStats:
    perf_model = perf_model or NPUPerfModel()
    server = InferenceServer(policy, SimExecutor(perf_model))
    return server.run(trace.fresh())
