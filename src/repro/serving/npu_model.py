"""Analytical NPU latency model (paper Table I + §V).

The paper uses a cycle-level simulator of a TPU-like systolic NPU
(128x128 @ 700 MHz, 360 GB/s, fixed-latency memory). The LazyBatching
scheduler only ever consumes *per-node latencies* — the paper itself reduces
them to a profiled lookup table — so we model each node execution as a
roofline term:

    latency = overhead + max(compute, memory)
    compute = sum_i flops_i(ctx_i) / (peak_flops · util · eff)
    memory  = (weight_bytes + sum_i bytes_i(ctx_i)) / mem_bw

where the compute term carries a systolic *fill penalty*
``(1 + fill_rows / (m_rows · batch))``: a weight-stationary array streams
``m_rows · batch`` activation rows per weight tile, and each tile costs an
extra ~fill_rows cycles of pipeline fill, so low-row nodes (FC layers,
decode steps) underutilise the MXU. Batching raises the row count AND
amortizes weight traffic — together these produce the paper's Fig. 3
throughput/latency tradeoff curve.

Two hardware profiles: the paper's NPU (Table I) for figure reproduction,
and TPU v5e for the roofline work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from .workload import NodeDesc, Workload


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # FLOP/s
    mem_bw: float              # bytes/s
    array_rows: int = 128
    fill_rows: int = 32        # per-tile pipeline fill cost (rows)
    sys_eff: float = 0.65      # sustained systolic efficiency
    node_overhead: float = 8e-6  # scheduling/dispatch overhead per node (s)


PAPER_NPU = HardwareSpec(
    name="paper-npu",
    peak_flops=2 * 128 * 128 * 700e6,     # 22.9 TFLOP/s (Table I)
    mem_bw=360e9,
)

TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    mem_bw=819e9,
    node_overhead=2e-6,
)


class NPUPerfModel:
    def __init__(self, hw: HardwareSpec = PAPER_NPU):
        self.hw = hw

    def node_latency(self, node: NodeDesc, ctxs: Sequence[int]) -> float:
        """Latency of executing ``node`` for a (merged) batch whose samples
        have context lengths ``ctxs``."""
        hw = self.hw
        flops = sum(node.sample_flops(c) for c in ctxs)
        act = sum(node.sample_bytes(c) for c in ctxs)
        m_eff = max(1, node.m_rows * len(ctxs))
        fill = 1.0 + hw.fill_rows / m_eff
        compute = flops * fill / (hw.peak_flops * hw.sys_eff) if flops else 0.0
        memory = (node.weight_bytes + act) / hw.mem_bw
        return hw.node_overhead + max(compute, memory)

    # ------------------------------------------------------------------
    def profile_table(self, wl: Workload, *, typical_ctx: Optional[int] = None
                      ) -> Dict[str, float]:
        """Single-batch per-node latency lookup table — the paper's one-time
        offline profiling pass (``NodeLatency(n)``, §IV-C). Conservative:
        decode nodes are profiled at the dec_timesteps-level context."""
        table = {}
        if typical_ctx is None:
            p = wl.prompt_dist.quantile(0.9) if wl.prompt_dist else 1
            d = wl.decode_dist.quantile(0.9) if wl.decode_dist else 0
            typical_ctx = max(1, p + d)
        for nid, node in wl.nodes.items():
            table[nid] = self.node_latency(node, [typical_ctx])
        return table

    def single_input_exec_time(self, wl: Workload, prompt_len: int,
                               decode_len: int) -> float:
        """Exact single-batch end-to-end time (Table II validation)."""
        seq, _, _ = wl.build_sequence(prompt_len, decode_len)
        return sum(self.node_latency(wl.nodes[nid], [ctx]) for nid, ctx in seq)
