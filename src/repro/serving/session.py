"""Online serving session: submit/stream front-end over the run-commit core.

The paper's premise is SLA-aware scheduling of a *live* request stream
across **co-located models sharing one NPU** (§VI-C). A
:class:`ServingSession` is that front-end: requests are submitted against
a :class:`~repro.serving.registry.ModelRegistry` of named models, each
with its *own* batching policy (and therefore its own per-graph
BatchTable and slack predictor — batching never crosses models), while a
cross-model :class:`~repro.core.arbiter.Arbiter` decides whose committed
run dispatches next on the one shared device clock:

    session = ServingSession(backend=SimExecutor(perf),
                             arbiter=LeastSlackArbiter())
    session.register("llama", wl_a, policy=LazyBatching(pred_a))
    session.register("mamba", wl_b, policy=LazyBatching(pred_b))
    h = session.submit(req, model="llama", on_token=lambda h, t: ...)
    session.run_until(t)        # incremental clock advancement
    session.step()              # ... or one scheduling step at a time
    h.state                     # QUEUED → ADMITTED → RUNNING → DONE
    session.drain()             # finish everything -> ServeStats

The single-model construction ``ServingSession(policy, backend)`` is
unchanged — it registers the policy under the ``"default"`` name and
every ``submit`` routes to it; with one registered model the arbiter is
never consulted, so results are bit-identical to the pre-registry
sessions. The scheduling core underneath is exactly the PR-2 run-commit
loop: each model's policy is consulted at every run boundary, commits a
run of consecutive node ids, the arbiter picks among the ready models,
and the backend executes the winner as one fused dispatch.

Device memory is part of admission: when the backend reports a bounded
KV pool (``memory_stats().max_slots``), the session wires each policy's
admission to the pool's free-slot budget — overflow defers in the InfQ,
per-model memory shares cap each tenant's residency, and (under
``reject_infeasible``) a request that cannot get a slot before its own
deadline is rejected at submit. See :meth:`ServingSession._mem_room`.

Handle lifecycle
----------------
``QUEUED``   — submitted, waiting in its model policy's InfQ (or in the
               session's future-arrivals queue when submitted ahead of its
               arrival time, e.g. trace replay);
``ADMITTED`` — the policy pulled it out of the InfQ into its batch state
               (``t_first_issue`` is set);
``REJECTED`` — refused at admission control (``reject_infeasible=True``
               and the request's own deadline is already unmeetable even
               if it ran alone immediately);
``RUNNING``  — a committed run containing the request has executed;
``DONE``     — finished; ``t_finish``/``latency``/``tokens`` are final.

Terminal failure/degradation states (all count as SLA violations):

``CANCELLED`` — the caller called ``handle.cancel()`` mid-flight;
``EXPIRED``   — ``cancel_expired=True`` and, at a run boundary, the
                request's deadline was provably blown (already past, or
                past even under the predictor's isolated-run bound) — it
                is evicted from its SubBatch and its KV slot freed so it
                stops stealing capacity from requests that can attain;
``FAILED``    — a backend fault (``BackendError``) consumed the request's
                retry budget (or was not retryable);
``SHED``      — dropped by graceful load shedding (bounded ingress queue
                overflow, or brownout mode protecting a higher tier).

Failure model
-------------
A ``BackendError`` from ``execute_run`` loses the whole dispatched run:
every member's device-side progress is discarded
(``Backend.reset_request`` — KV slot released idempotently, no leaks)
and, per the session's :class:`RetryPolicy`, members are requeued with
capped exponential backoff + deterministic jitter (virtual time in sim,
wall-clock in JAX — both are the one session clock) to replay prefill
from node 0. SLA accounting always judges the ORIGINAL deadline: retries
buy a response, never absolution. Eviction — cancellation, expiry,
fault requeue — never perturbs surviving batch members: they keep their
slots, caches, and (in the JAX engine) bit-exact tokens.

Streaming
---------
At every run boundary the session asks the backend how many response
tokens each just-executed request has produced (decode megasteps already
hold the sampled tokens — the JAX engine surfaces them; the simulator
reports virtual tokens, one per completed decode cycle). New tokens fire
the handle's ``on_token(handle, token)`` callback, stamp
``t_first_token`` (TTFT), and accumulate in ``handle.tokens`` — for the
JAX backend these are bit-exact the batch ``execute_run`` results.

Compatibility
-------------
``run_trace(policy, backend, trace)`` replays an offline trace through a
single-model session and returns the familiar :class:`ServeStats`;
``run_mixture(models, backend, trace)`` is its multi-tenant sibling
(requests route on their ``model`` tag); ``InferenceServer.run`` and
``run_policy`` are thin wrappers over ``run_trace``, so every
pre-existing experiment script and test runs unmodified.
"""
from __future__ import annotations

import heapq
import itertools
from enum import Enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from dataclasses import dataclass
from collections import deque

from ..core import lifecycle
from ..core.arbiter import Arbiter, LeastSlackArbiter
from ..core.policies import Policy
from ..core.request import Request
from .backend import Backend, BackendError, ServerLog, run_label
from .metrics import ServeStats
from .registry import ModelEntry, ModelRegistry
from .traffic import Trace

DEFAULT_MODEL = "default"

#: Handle lifecycle states, DERIVED from the declarative state machine in
#: :mod:`repro.core.lifecycle` (the same table the ``handle-lattice``
#: static checker enforces): QUEUED / ADMITTED / RUNNING / DONE /
#: REJECTED / CANCELLED / EXPIRED / FAILED / SHED, with the legal edges
#: (monotone-except-retry) in ``lifecycle.EDGES``.
HandleState = Enum("HandleState",
                   {name.upper(): name for name in lifecycle.STATES})

#: request.fate value -> terminal HandleState (one entry per declared
#: lifecycle fate — the table, not this module, says what fates exist)
_FATE_STATE = {fate: HandleState(fate) for fate in lifecycle.FATES}


@dataclass(frozen=True)
class RetryPolicy:
    """Fault-retry semantics for ``BackendError`` dispatch faults.

    A transiently failed request is requeued ``max_retries`` times with
    capped exponential backoff — attempt ``k`` waits
    ``min(backoff_base * 2**(k-1), backoff_cap)`` scaled by a
    deterministic jitter draw in ``[1, 1+jitter]`` from the session's
    seeded retry stream. Exhaustion (or a non-retryable fault) turns the
    request terminal ``FAILED``. ``max_retries=0`` fails every faulted
    request immediately."""
    max_retries: int = 3
    backoff_base: float = 0.002       # seconds (session clock)
    backoff_cap: float = 0.5
    jitter: float = 0.25              # max fractional extra backoff

    def __post_init__(self):
        if self.max_retries < 0 or self.backoff_base < 0 \
                or self.backoff_cap < self.backoff_base or self.jitter < 0:
            raise ValueError(f"invalid RetryPolicy: {self}")

    def backoff(self, attempt: int, rng) -> float:
        """Backoff before retry ``attempt`` (1-based), jittered."""
        base = min(self.backoff_base * (2.0 ** (attempt - 1)),
                   self.backoff_cap)
        return base * (1.0 + self.jitter * float(rng.random()))


@dataclass(frozen=True)
class BrownoutConfig:
    """Attainment-triggered brownout: when the PROTECTED tier's rolling
    attainment (a window over its last ``window`` terminal outcomes,
    evaluated once ``min_samples`` exist) drops below ``floor``, the
    session sheds all queued + arriving work of strictly lower
    ``shed_priority`` models until attainment recovers above
    ``floor + hysteresis``. The protected tier is the highest registered
    ``shed_priority``; with a single priority level brownout never
    engages (there is nothing lower-tier to shed)."""
    floor: float = 0.9
    window: int = 64
    hysteresis: float = 0.05
    min_samples: int = 16

    def __post_init__(self):
        if not 0.0 < self.floor <= 1.0 or self.window < 1 \
                or self.hysteresis < 0 or self.min_samples < 1:
            raise ValueError(f"invalid BrownoutConfig: {self}")


class RequestHandle:
    """Caller-facing view of one submitted request's lifecycle."""

    def __init__(self, req: Request, session: "ServingSession",
                 on_token: Optional[Callable] = None,
                 model: Optional[str] = None):
        self.request = req
        self._session = session
        self.t_submit = session.now
        self.on_token = on_token
        # registry name of the entry serving this request (authoritative
        # routing key — independent of the request's reporting tag)
        self.model = model
        self.tokens: List[int] = []     # streamed response tokens so far
        self._n_tokens = 0
        self._rejected = False
        self._running = False

    @property
    def state(self) -> HandleState:
        """Derived, monotone lifecycle state (no per-step bookkeeping)."""
        if self._rejected:
            return HandleState.REJECTED
        r = self.request
        if r.fate is not None:
            return _FATE_STATE[r.fate]
        if r.done:
            return HandleState.DONE
        if self._running:
            return HandleState.RUNNING
        if r.t_first_issue is not None:
            return HandleState.ADMITTED
        return HandleState.QUEUED

    _TERMINAL = frozenset(HandleState(s) for s in lifecycle.TERMINAL)

    @property
    def done(self) -> bool:
        """Terminal: the request will never run (again) — completed,
        refused, cancelled, expired, failed, or shed."""
        return self.state in self._TERMINAL

    @property
    def retries(self) -> int:
        """Fault-retry attempts consumed so far."""
        return self.request.retries

    def cancel(self) -> bool:
        """Cancel this request mid-flight: evict it from its model's
        scheduling state (InfQ or SubBatch — surviving batch members are
        untouched) and free its KV slot immediately. Terminal state
        becomes ``CANCELLED``; tokens streamed so far stay readable.
        Returns ``False`` (no-op) when the handle is already terminal."""
        return self._session.cancel(self)

    @property
    def t_first_token(self) -> Optional[float]:
        return self.request.t_first_token

    @property
    def t_finish(self) -> Optional[float]:
        return self.request.t_finish

    @property
    def latency(self) -> Optional[float]:
        r = self.request
        return None if r.t_finish is None else r.t_finish - r.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (from arrival)."""
        r = self.request
        return (None if r.t_first_token is None
                else r.t_first_token - r.arrival)

    def __repr__(self):
        return (f"RequestHandle(rid={self.request.rid}, "
                f"state={self.state.value}, tokens={len(self.tokens)})")


class ServingSession:
    """Online serving front-end over a model registry and one backend.

    ``policy`` (positional, optional): single-model convenience — the
    policy is registered under the ``"default"`` model name, preserving
    the pre-registry ``ServingSession(policy, backend)`` construction
    bit-identically. Multi-tenant sessions omit it and call
    :meth:`register` per model instead.

    ``arbiter``: cross-model dispatch order when several registered
    models have committed runs ready (default
    :class:`~repro.core.arbiter.LeastSlackArbiter`, the paper's SLA-aware
    behavior; never consulted with a single registered model).

    ``reject_infeasible``: when a model's policy carries a slack
    predictor, refuse at submit time any request whose own deadline is
    unmeetable even running alone immediately (conservative single-input
    bound) — the handle goes straight to ``REJECTED`` instead of burning
    batch slack on a guaranteed violation. Off by default (the paper's
    system never drops work).

    ``memory_aware``: when the backend reports a bounded KV pool
    (``memory_stats().max_slots`` set — e.g. ``JaxEngine(max_slots=...)``
    or ``SimExecutor(max_slots=...)``), wire each registered policy's
    admission to the pool's free-slot budget: admission beyond free
    memory defers in the InfQ, and per-model memory shares (from
    ``register(mem_share=...)`` or the arbiter's ``mem_shares``) cap each
    tenant's resident slots. On by default — a no-op until a backend
    actually reports a cap; ``False`` restores fully memory-blind
    scheduling for A/B comparison.

    ``seed`` feeds the RNG handed to ``Backend.prepare`` (the JAX engine
    samples synthetic prompts from it when none is supplied).

    Failure & degradation knobs (all default to the pre-failure-model
    behavior bit-identically):

    ``cancel_expired``: at every run boundary, expire (terminal
    ``EXPIRED``, slot freed, batch survivors untouched) any request whose
    deadline is provably blown — already past, or unreachable even under
    the predictor's conservative isolated-run bound
    (``single_remaining``). Off by default (the paper's system never
    drops work).

    ``retry``: the :class:`RetryPolicy` that ARMS the failure model —
    when set, a ``BackendError`` from a dispatch is absorbed: retryable
    faults requeue with capped exponential backoff and deterministic
    jitter, everything else (and budget exhaustion) goes terminal
    ``FAILED``. ``None`` (the default) leaves the failure model off:
    backend errors propagate to the caller exactly as before — an
    engine's own "arena exhausted / memory cap" errors stay loud unless
    the caller opted into fault handling.

    ``max_queue``: bounded ingress queue — when the total InfQ backlog
    (across models) is at the bound, an arriving request triggers
    deadline-aware shedding: the least valuable of (backlog + newcomer)
    — lowest ``shed_priority`` tier first, loosest absolute deadline
    within a tier — goes terminal ``SHED``. ``None`` = unbounded.

    ``brownout``: a :class:`BrownoutConfig` enabling attainment-triggered
    tier shedding via ``register(..., shed_priority=...)``.
    """

    def __init__(self, policy: Optional[Policy] = None,
                 backend: Optional[Backend] = None, *,
                 arbiter: Optional[Arbiter] = None, seed: int = 0,
                 reject_infeasible: bool = False,
                 memory_aware: bool = True,
                 cancel_expired: bool = False,
                 retry: Optional[RetryPolicy] = None,
                 max_queue: Optional[int] = None,
                 brownout: Optional[BrownoutConfig] = None,
                 log: Optional[ServerLog] = None):
        if backend is None:
            raise ValueError(
                "ServingSession requires a backend — pass SimExecutor(...) "
                "or a JaxEngine-backed MultiBackend")
        self.registry = ModelRegistry()
        self.backend = backend
        self.arbiter = arbiter if arbiter is not None else LeastSlackArbiter()
        self.log = log if log is not None else ServerLog()
        self.now = 0.0
        self.duration: Optional[float] = None    # reporting window override
        self.reject_infeasible = reject_infeasible
        self.memory_aware = memory_aware
        self.cancel_expired = cancel_expired
        self.retry = retry          # None = failure model off (errors raise)
        self.max_queue = max_queue
        self.brownout = brownout
        self.handles: Dict[int, RequestHandle] = {}
        self._finished: Dict[int, Request] = {}   # rid-keyed: O(1) release
        self._rejected: Dict[int, Request] = {}
        # terminal failure/degradation dispositions, keyed like _finished:
        # one bucket per fate the lifecycle table declares
        self._disposed: Dict[str, Dict[int, Request]] = {
            fate: {} for fate in lifecycle.FATES}
        self.retried = 0                 # fault-retry requeue events
        self.brownouts = 0               # brownout activations
        self._brownout_active = False
        self._attain_window: deque = deque(
            maxlen=brownout.window if brownout is not None else 1)
        self._rng = np.random.default_rng(seed)
        # separate stream for retry jitter: backoff draws must never
        # perturb prompt sampling (survivors stay bit-exact vs fault-free)
        self._retry_rng = np.random.default_rng([seed, 0x5EED])
        self._arrivals: list = []        # heap of (t, rid, seq, req, entry)
        self._seq = itertools.count()
        self._classes: Dict[str, Optional[float]] = {}
        # observer hook, fired after each executed run (and after fault
        # handling): on_run_boundary(session, model_name, done_requests).
        # The serving gateway wires its metrics registry here so queue
        # depth / arena residency / run counters are sampled at every
        # scheduling boundary without polling.
        self.on_run_boundary: Optional[Callable] = None
        if policy is not None:
            self.register(DEFAULT_MODEL, policy=policy)

    # ------------------------------------------------------------------
    # Model registry
    # ------------------------------------------------------------------
    def register(self, name: str, workload=None, *, policy: Policy,
                 mem_share: Optional[float] = None,
                 shed_priority: int = 0) -> ModelEntry:
        """Register a model: ``name`` becomes the routing key for
        ``submit(model=...)``, trace tags, backend muxing, and per-model
        stats; ``policy`` is the model's private batching policy (its own
        BatchTable / slack predictor — batching never crosses models).
        ``workload`` is advisory: when given, submitted requests are
        checked against it. ``mem_share`` caps the model's resident KV
        slots at that fraction of its backend pool's ``max_slots`` under
        memory-aware admission (falls back to the arbiter's
        ``mem_shares``). ``shed_priority`` ranks the model for graceful
        load shedding (higher = protected; lower tiers shed first under
        ingress overflow or brownout)."""
        entry = self.registry.register(name, workload, policy=policy,
                                       mem_share=mem_share,
                                       shed_priority=shed_priority)
        if self.memory_aware:
            # the gate re-reads backend stats on every admission decision,
            # so it tracks arena growth/shrink and cross-model usage live
            entry.policy.mem_gate = (lambda e=entry: self._mem_room(e))
        else:
            # a policy instance reused from a memory-aware session must not
            # keep that session's gate
            entry.policy.mem_gate = None
        return entry

    def _mem_share(self, entry: ModelEntry) -> Optional[float]:
        if entry.mem_share is not None:
            return entry.mem_share
        return self.arbiter.mem_share(entry.name)

    def _mem_room(self, entry: ModelEntry) -> Optional[int]:
        """New admissions ``entry`` may make before oversubscribing device
        memory (None = the backend reports no cap — memory-blind).

        Usage is counted from the policies' *admitted* sets, not the
        backend's live slots: a request holds its KV slot from admission
        (its first dispatch is imminent) to completion, and counting at
        the admission layer closes the window where several models could
        admit against the same free slot in one scheduling step. Models
        whose stats report the same ``pool`` contend for the same slots
        (one shared simulated device); per-model engines behind a
        MultiBackend each own a disjoint pool.

        A model's share is BOTH a cap on its own residency and a
        reservation against everyone else: other pool tenants can never
        admit into the unused remainder of a shared model's reserved
        slots, so an uncapped bulk tenant cannot starve a shared
        interactive tenant either."""
        stats = self.backend.memory_stats(entry.name)
        if stats is None or stats.max_slots is None:
            return None
        used_pool = 0
        reserved_unused = 0          # other tenants' untouched reservations
        for e in self.registry.entries():
            if e is entry:
                used_pool += e.policy.admitted
                continue
            st = self.backend.memory_stats(e.name)
            if st is not None and st.pool == stats.pool:
                used_pool += e.policy.admitted
                other_share = self._mem_share(e)
                if other_share is not None:
                    cap_other = max(1, int(other_share * stats.max_slots))
                    reserved_unused += max(0, cap_other - e.policy.admitted)
        room = stats.max_slots - used_pool - reserved_unused
        share = self._mem_share(entry)
        if share is not None:
            cap = max(1, int(share * stats.max_slots))
            room = min(room, cap - entry.policy.admitted)
        return max(0, room)

    def _resolve_model(self, model: Optional[str],
                       req: Request) -> ModelEntry:
        """Routing precedence: explicit ``model`` argument > sole
        registered model (single-model sessions accept every request —
        legacy compat; a foreign workload is still rejected by the
        submit-time workload check) > the request's own ``model`` tag.
        Ambiguous (multi-model, untagged) submissions raise."""
        entries = self.registry.entries()
        if not entries:
            raise RuntimeError(
                "no model registered — call session.register() first")
        if model is not None:
            return self.registry[model]
        if len(entries) == 1:
            return entries[0]
        if req.model is not None:
            return self.registry[req.model]
        raise ValueError(
            f"request {req.rid} carries no model tag and session serves "
            f"{self.registry.names()} — pass submit(model=...)")

    @property
    def policy(self) -> Policy:
        """The sole registered model's policy (single-model compat)."""
        entries = self.registry.entries()
        if len(entries) != 1:
            raise RuntimeError(
                "session.policy is single-model only — use "
                "session.registry[name].policy")
        return entries[0].policy

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, req: Request, *, model: Optional[str] = None,
               prompt_tokens=None,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Register a request with the session and return its handle.

        ``model`` routes the request to a registered model; omitted, a
        single-model session serves it unconditionally (legacy compat),
        while a multi-model session falls back to the request's own
        ``model`` tag (traffic mixtures stamp one) and raises when that
        is missing too. ``req.arrival`` in the future
        (relative to the session clock) is honored — the request enters
        its model policy's InfQ when the clock reaches it (trace replay);
        an arrival in the past is clamped to *now* (live submission —
        waiting time, slack, and latency all count from the submission
        instant, not a stale timestamp). ``on_token(handle, token)`` fires
        once per response token at the producing run's boundary.
        """
        if req.rid in self.handles:
            raise ValueError(f"rid {req.rid} already submitted — clone the "
                             f"request to resubmit the same trace entry")
        entry = self._resolve_model(model, req)
        # workloads are compared by name, not identity: PAPER_WORKLOADS /
        # get_workload return a fresh instance per call, and same-name
        # workloads share profile tables (slack predictors key on name)
        if (entry.workload is not None
                and req.workload is not entry.workload
                and getattr(req.workload, "name", None)
                != entry.workload.name):
            raise ValueError(
                f"request {req.rid} was built for workload "
                f"{getattr(req.workload, 'name', '?')!r} but model "
                f"{entry.name!r} serves {entry.workload.name!r}")
        if len(self.registry) > 1:
            # normalize the reporting tag to the registry name; sole-model
            # sessions leave it alone so untagged requests keep the
            # per-workload ``model_name`` fallback in ServeStats.per_model
            # (the handle carries the authoritative routing key either way)
            req.model = entry.name
        req.arrival = max(req.arrival, self.now)
        handle = RequestHandle(req, self, on_token=on_token,
                               model=entry.name)
        self.handles[req.rid] = handle
        deadline = req.sla.deadline if req.sla else None
        prev = self._classes.setdefault(req.sla_name, deadline)
        if prev != deadline:
            del self.handles[req.rid]
            raise ValueError(
                f"SLA class {req.sla_name!r} submitted with deadline "
                f"{deadline} but previously seen with {prev} — per-class "
                f"reporting needs one deadline per class name")
        if self.reject_infeasible and self._infeasible(entry, req):
            handle._rejected = True
            self._rejected[req.rid] = req
            # the feasibility probe may have memoized predictor state for a
            # request the policy will never see finish — release it here
            entry.policy.request_finished([req])
            return handle
        self.backend.prepare(entry.name, req, self._rng,
                             prompt_tokens=prompt_tokens)
        # same-timestamp arrivals (co-located models replaying one trace)
        # tiebreak on rid — an intrinsic, submission-order-independent key —
        # so cross-model enqueue order never depends on registration or
        # trace-assembly dict order (the session seq is a last-resort
        # tiebreak for exotic cloned-rid submissions only)
        heapq.heappush(self._arrivals,
                       (req.arrival, req.rid, next(self._seq), req, entry))
        return handle

    def _infeasible(self, entry: ModelEntry, req: Request) -> bool:
        # arrival is already clamped to the session clock, so the deadline
        # window opens now: unmeetable iff even an isolated immediate run
        # (the conservative single-input bound) overshoots it
        pred = getattr(entry.policy, "predictor", None)
        if pred is None or not hasattr(pred, "single_total"):
            return False
        if pred.single_total(req) > pred.deadline(req):
            return True
        # memory-infeasible: the model's KV pool is exhausted AND — by the
        # predictor's own per-request bounds — no resident request can
        # release a slot early enough for this one to still meet its
        # deadline (projected footprint cannot fit before the deadline).
        # The slot is only needed at the request's ARRIVAL: a future
        # arrival absorbs (part of) the release wait, so trace-style
        # ahead-of-time submissions are never rejected for congestion
        # that clears before they arrive.
        if self.memory_aware and hasattr(pred, "release_bound"):
            room = self._mem_room(entry)
            if room == 0:
                wait = max(0.0,
                           pred.release_bound(entry.policy.admitted_requests)
                           - (req.arrival - self.now))
                return wait + pred.single_total(req) > pred.deadline(req)
        return False

    # ------------------------------------------------------------------
    # Clock advancement
    # ------------------------------------------------------------------
    def _enqueue_due(self):
        while self._arrivals and self._arrivals[0][0] <= self.now + 1e-12:
            _, _, _, req, entry = heapq.heappop(self._arrivals)
            if req.terminal:        # cancelled/shed while future-queued
                continue
            if (self._brownout_active
                    and entry.shed_priority < self._protected_priority()):
                self._terminate(self.handles.get(req.rid), "shed")
                continue
            if self.max_queue is not None:
                self._bound_ingress(req, entry)
                if req.terminal:    # the newcomer itself was the victim
                    continue
            entry.policy.enqueue(req, self.now)

    # ------------------------------------------------------------------
    # Failure model: cancellation, expiry, faults, shedding
    # ------------------------------------------------------------------
    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel ``handle``'s request mid-flight (see
        :meth:`RequestHandle.cancel`). Returns ``False`` when already
        terminal."""
        return self._terminate(handle, "cancelled")

    def _terminate(self, handle: Optional[RequestHandle],
                   fate: str) -> bool:
        """Make a live request terminal with ``fate`` (``cancelled`` /
        ``expired`` / ``failed`` / ``shed``): physically evict it from
        its model's scheduling state (InfQ or SubBatch — survivors are
        untouched), free its backend resources (KV slot) immediately,
        and record it for stats. Idempotent: a terminal handle is a
        no-op (returns ``False``)."""
        if handle is None or handle.done:
            return False
        req = handle.request
        entry = self.registry[handle.model]
        req.fate = fate
        # evict BEFORE touching backend state: the policy drops it from
        # its InfQ / SubBatch via the same live-filtering a finished
        # request takes, so the batch-table invariants never see it
        entry.policy.cancel([req])
        # batch release + single reclaim; idempotent when it never held
        # a slot (e.g. cancelled while future-queued)
        self.backend.on_finished(entry.name, [req])
        entry.policy.request_finished([req])
        self._disposed[fate][req.rid] = req
        if fate != "cancelled":      # caller choice is not a QoS outcome
            self._note_outcome(entry, ok=False)
        return True

    def _rel_deadline(self, req: Request,
                      entry: ModelEntry) -> Optional[float]:
        """The request's relative deadline as the scheduler sees it: its
        model predictor's view (per-request SLA class, else the
        predictor's global target) — without a predictor, the SLA class
        alone (None = no deadline, never expires)."""
        pred = getattr(entry.policy, "predictor", None)
        if pred is not None and hasattr(pred, "deadline"):
            return pred.deadline(req)
        return req.sla.deadline if req.sla is not None else None

    def _abs_deadline(self, req: Request,
                      entry: ModelEntry) -> Optional[float]:
        rel = self._rel_deadline(req, entry)
        return None if rel is None else req.arrival + rel

    def _expire_due(self):
        """Run-boundary expiry sweep (``cancel_expired=True``): turn
        terminal-``EXPIRED`` every queued or admitted request whose
        deadline is provably blown — the clock is already past it, or
        even the predictor's conservative isolated-run bound
        (``single_remaining``, the mid-flight continuation of the
        ``reject_infeasible`` single bound) cannot land before it. An
        expired batch member is evicted and its slot freed so it stops
        burning device time the survivors could attain with."""
        for entry in self.registry.entries():
            pred = getattr(entry.policy, "predictor", None)
            rem = getattr(pred, "single_remaining", None)
            pending = list(entry.policy.queue) \
                + list(entry.policy.admitted_requests)
            for req in pending:
                if req.terminal:
                    continue
                dl = self._abs_deadline(req, entry)
                if dl is None:
                    continue
                blown = self.now > dl + 1e-12
                if not blown and rem is not None:
                    blown = self.now + rem(req) > dl + 1e-12
                if blown:
                    self._terminate(self.handles.get(req.rid), "expired")

    def _bound_ingress(self, req: Request, entry: ModelEntry):
        """Bounded ingress (``max_queue``): when the total InfQ backlog
        is at the bound, shed the least valuable of (backlog +
        newcomer) — lowest ``shed_priority`` tier first, loosest
        absolute deadline (most slack to give up) within a tier,
        newest arrival as the tiebreak."""
        depth = sum(len(e.policy.queue) for e in self.registry.entries())
        if depth < self.max_queue:
            return
        cands = [(e, r) for e in self.registry.entries()
                 for r in e.policy.queue]
        cands.append((entry, req))

        def _key(pair):
            e, r = pair
            dl = self._abs_deadline(r, e)
            # no deadline = infinitely loose = first to go within a tier
            return (e.shed_priority,
                    -dl if dl is not None else -float("inf"),
                    -r.arrival)

        victim_e, victim_r = min(cands, key=_key)
        self._terminate(self.handles.get(victim_r.rid), "shed")

    def _protected_priority(self) -> int:
        return max((e.shed_priority for e in self.registry.entries()),
                   default=0)

    def _note_outcome(self, entry: ModelEntry, ok: bool):
        """Feed the brownout controller one terminal outcome of the
        PROTECTED tier (finish-within-deadline = ok; late finish,
        expiry, fault-failure, shed = not ok)."""
        if self.brownout is None:
            return
        if entry.shed_priority != self._protected_priority():
            return
        self._attain_window.append(1 if ok else 0)
        cfg = self.brownout
        if len(self._attain_window) < cfg.min_samples:
            return
        att = sum(self._attain_window) / len(self._attain_window)
        if not self._brownout_active and att < cfg.floor:
            self._brownout_active = True
            self.brownouts += 1
            self._brownout_shed()
        elif self._brownout_active and att >= cfg.floor + cfg.hysteresis:
            self._brownout_active = False

    def _brownout_shed(self):
        """Brownout activation: shed every QUEUED (not yet admitted —
        admitted work already holds slots and finishes soon) request of
        strictly lower-priority models; arrivals keep shedding at the
        ingress while the brownout stays active."""
        prot = self._protected_priority()
        for entry in self.registry.entries():
            if entry.shed_priority >= prot:
                continue
            for req in list(entry.policy.queue):
                self._terminate(self.handles.get(req.rid), "shed")

    def _on_fault(self, entry: ModelEntry, sb, reqs: List[Request],
                  err: BackendError):
        """A dispatched run raised ``BackendError``: the whole run's
        device-side progress is lost. Members are evicted from the
        batch, their slots/caches discarded (``reset_request`` — KV is
        gone, so a retry replays prefill from node 0), and each is
        either requeued with capped exponential backoff + deterministic
        jitter or turned terminal ``FAILED`` (non-retryable fault or
        retry budget exhausted). The fault's own latency burns device
        time (``busy_time``) but commits no nodes; SLA accounting keeps
        judging the ORIGINAL arrival/deadline."""
        lat = float(err.latency)
        self.log.faults += 1
        self.log.busy_time += lat
        self.log.busy_by_model[entry.name] = (
            self.log.busy_by_model.get(entry.name, 0.0) + lat)
        self.now += lat
        # evict from the SubBatch first, while member idx values still
        # satisfy the common-node invariant — THEN rewind per-request
        entry.policy.cancel(reqs)
        for req in reqs:
            # idempotent device-side discard: slot released, engine state
            # rewound to post-prepare (prompt intact, KV/progress gone)
            self.backend.reset_request(entry.name, req)
            handle = self.handles.get(req.rid)
            if err.retryable and req.retries < self.retry.max_retries:
                entry.policy.request_finished([req])   # predictor forgets
                req.retries += 1
                self.retried += 1
                req.idx = 0                  # prefill replay from node 0
                req.t_first_issue = None
                if handle is not None:
                    handle._running = False
                delay = self.retry.backoff(req.retries, self._retry_rng)
                heapq.heappush(
                    self._arrivals,
                    (self.now + delay, req.rid, next(self._seq), req,
                     entry))
            else:
                self._terminate(handle, "failed")

    def step(self, limit: Optional[float] = None) -> bool:
        """One scheduling step: enqueue due arrivals, collect each model
        policy's next committed run, let the arbiter pick one, and execute
        it (clock advances by its latency) — or, with no run ready, jump
        the clock to the next event (arrival / earliest policy timer).
        Returns ``False`` when fully idle — nothing queued, running, or
        pending — or when the next event lies beyond ``limit``.

        Consulting ``next_work`` commits admission state (batch
        formation, ``t_first_issue``) for EVERY model with ready work at
        this run boundary, not just the arbiter's winner — deliberately:
        host-side admission proceeds while the device is busy with
        another model's run, exactly as the paper's co-located stacks
        admit into their BatchTables between dispatches. A non-dispatched
        model's formed batch simply stays parked (its policy returns the
        same work next step) and burns waiting time until the arbiter
        picks it."""
        self._enqueue_due()
        if self.cancel_expired:
            self._expire_due()
        entries = self.registry.entries()
        candidates: List[Tuple[ModelEntry, object, Tuple[str, ...]]] = []
        for entry in entries:
            work = entry.policy.next_work(self.now)
            if work is not None:
                candidates.append((entry, work[0], work[1]))
        if not candidates:
            nxt = []
            if self._arrivals:
                nxt.append(self._arrivals[0][0])
            for entry in entries:
                t = entry.policy.next_timer(self.now)
                if t is not None:
                    nxt.append(max(t, self.now))
            if not nxt:
                return False                      # fully drained
            target = min(nxt)
            if limit is not None and target > limit:
                self.now = max(self.now, limit)
                return False
            self.now = target
            return True

        if len(entries) == 1:          # single-model: bit-exact legacy path
            entry, sb, run = candidates[0]
        else:
            # multi-model sessions consult the arbiter even for a single
            # candidate so stateful arbiters (round-robin's cursor) see
            # every dispatch, not just the contended ones
            entry, sb, run = candidates[self.arbiter.pick(candidates,
                                                          self.now)]
        reqs = list(sb.live_requests)
        try:
            latency, per_node = self.backend.execute_run(entry.name, sb, run)
        except BackendError as err:
            if self.retry is None:
                raise       # no retry policy armed: pre-failure-model
            self._on_fault(entry, sb, reqs, err)
            if self.on_run_boundary is not None:
                self.on_run_boundary(self, entry.name, [])
            return True
        self.log.nodes_executed += len(run)
        self.log.runs_executed += 1
        self.log.busy_time += latency
        self.log.batch_size_sum += sb.size * len(run)
        self.log.busy_by_model[entry.name] = (
            self.log.busy_by_model.get(entry.name, 0.0) + latency)
        prefix = f"{entry.name}:" if len(entries) > 1 else ""
        if per_node is not None:
            for nid, lat in zip(run, per_node):
                self.log.record(prefix + nid, lat)
        else:
            self.log.record(prefix + run_label(run), latency, n=len(run))
        self.now += latency
        done_now = entry.policy.work_done(sb, self.now, len(run))
        # observe (stream tokens, stamp TTFT) BEFORE the completion hooks:
        # backends may release per-request device resources there
        for r in reqs:
            self._observe(entry, r)
        if done_now:
            self.backend.on_finished(entry.name, done_now)
            entry.policy.request_finished(done_now)
        for r in done_now:
            self._finished[r.rid] = r
            dl = self._rel_deadline(r, entry)
            self._note_outcome(entry,
                               ok=(dl is None or r.latency() <= dl + 1e-12))
        if self.on_run_boundary is not None:
            self.on_run_boundary(self, entry.name, done_now)
        return True

    def _observe(self, entry: ModelEntry, req: Request):
        """Run-boundary bookkeeping for one just-executed request: state
        transition to RUNNING, TTFT stamp, token streaming."""
        handle = self.handles.get(req.rid)
        if handle is None:
            return
        handle._running = True
        n = self.backend.token_count(entry.name, req)
        if n <= handle._n_tokens:
            return
        if req.t_first_token is None:
            req.t_first_token = self.now
        toks = self.backend.tokens(entry.name, req)
        new = (list(toks[handle._n_tokens:n]) if toks is not None
               else [-1] * (n - handle._n_tokens))   # virtual tokens (sim)
        handle._n_tokens = n
        handle.tokens.extend(new)
        if handle.on_token is not None:
            for t in new:
                handle.on_token(handle, t)

    def run_until(self, t: float) -> float:
        """Advance the session clock to (at least) ``t``, executing every
        run that *starts* at or before ``t`` — a run in flight at the
        boundary completes (the clock only advances at run boundaries).
        Returns the clock."""
        while self.now <= t:
            if not self.step(limit=t):
                break
        self.now = max(self.now, t)
        return self.now

    def drain(self, *, stall_limit: int = 1000) -> ServeStats:
        """Run everything outstanding to completion and return stats.

        Liveness guard: a step that reports progress (``True``) must
        change *something* observable — the clock, a run/fault count, a
        retry, or a terminal disposition. ``stall_limit`` consecutive
        steps with an identical progress signature mean the scheduler is
        livelocked (e.g. a policy re-offering work the backend can never
        place); rather than spinning forever, drain raises a
        ``RuntimeError`` carrying per-model queue/backlog diagnostics."""
        last_sig = None
        stalls = 0
        while self.step():
            sig = (self.now, self.log.runs_executed, self.log.faults,
                   self.retried, self.outstanding, len(self._finished),
                   *(len(d) for d in self._disposed.values()))
            if sig == last_sig:
                stalls += 1
                if stalls >= stall_limit:
                    backlog = {e.name: {"queued": len(e.policy.queue),
                                        "admitted": e.policy.admitted}
                               for e in self.registry.entries()}
                    raise RuntimeError(
                        f"drain() livelocked: no observable progress for "
                        f"{stall_limit} consecutive steps at "
                        f"t={self.now:.6f} — future arrivals="
                        f"{len(self._arrivals)}, outstanding="
                        f"{self.outstanding}, per-model backlog={backlog}")
            else:
                stalls = 0
                last_sig = sig
        return self.stats()

    def release(self, handle: RequestHandle) -> None:
        """Drop a finished/rejected handle's per-request state from the
        session (long-lived online sessions otherwise accumulate every
        handle, request, and token list ever submitted). The request no
        longer contributes to :meth:`stats`.

        Only terminal handles (DONE / REJECTED / CANCELLED / EXPIRED /
        FAILED / SHED) may be released: a QUEUED / ADMITTED / RUNNING
        request's scheduler and backend state is live, and silently
        dropping the session's view of it mid-flight would orphan
        tokens, stats, and KV slots — raises ``ValueError`` (a real
        error, not an ``assert``, so it cannot be optimized away)."""
        if not handle.done:
            raise ValueError(
                f"cannot release live request {handle.request.rid} "
                f"(state={handle.state.value}): only terminal handles "
                f"may be released — wait for completion or drain first")
        req = handle.request
        self.handles.pop(req.rid, None)
        self._finished.pop(req.rid, None)
        self._rejected.pop(req.rid, None)
        for bucket in self._disposed.values():
            bucket.pop(req.rid, None)
        self.backend.release_request(handle.model, req)

    # ------------------------------------------------------------------
    @property
    def outstanding(self) -> int:
        return len(self._arrivals) + sum(e.policy.outstanding
                                         for e in self.registry.entries())

    @property
    def finished(self) -> List[Request]:
        return list(self._finished.values())

    @property
    def rejected(self) -> List[Request]:
        return list(self._rejected.values())

    @property
    def cancelled(self) -> List[Request]:
        return list(self._disposed["cancelled"].values())

    @property
    def expired(self) -> List[Request]:
        return list(self._disposed["expired"].values())

    @property
    def failed(self) -> List[Request]:
        return list(self._disposed["failed"].values())

    @property
    def shed(self) -> List[Request]:
        return list(self._disposed["shed"].values())

    def stats(self) -> ServeStats:
        duration = self.duration if self.duration is not None else self.now
        entries = self.registry.entries()
        if len(entries) == 1:
            pname = entries[0].policy.name
        else:
            pname = (self.arbiter.name + "["
                     + "+".join(f"{e.name}:{e.policy.name}" for e in entries)
                     + "]")
        return ServeStats(policy=pname, duration=duration,
                          finished=list(self._finished.values()),
                          rejected=len(self._rejected),
                          rejected_requests=list(self._rejected.values()),
                          cancelled_requests=self.cancelled,
                          expired_requests=self.expired,
                          failed_requests=self.failed,
                          shed_requests=self.shed,
                          retried=self.retried,
                          classes=dict(self._classes),
                          models={e.name: e.policy.name for e in entries})


def run_trace(policy: Policy, backend: Backend, trace: Trace, *,
              drain: bool = True, seed: int = 0,
              log: Optional[ServerLog] = None,
              reject_infeasible: bool = False,
              memory_aware: bool = True) -> ServeStats:
    """Offline-compatibility wrapper: replay a whole trace through a
    single-model :class:`ServingSession` and return its
    :class:`ServeStats` — the ``InferenceServer.run(trace)`` contract,
    now a thin shim."""
    session = ServingSession(policy, backend, seed=seed, log=log,
                             reject_infeasible=reject_infeasible,
                             memory_aware=memory_aware)
    session.duration = trace.duration
    for req in sorted(trace.requests, key=lambda r: r.arrival):
        session.submit(req)
    if drain:
        return session.drain()
    session.run_until(trace.duration)
    return session.stats()


def run_mixture(models: Sequence[Tuple[str, object, Policy]],
                backend: Backend, trace: Trace, *,
                arbiter: Optional[Arbiter] = None, drain: bool = True,
                seed: int = 0, log: Optional[ServerLog] = None,
                reject_infeasible: bool = False,
                memory_aware: bool = True) -> ServeStats:
    """Multi-tenant sibling of :func:`run_trace`: register every
    ``(name, workload, policy)`` triple, replay a (model-tagged) trace —
    e.g. from :func:`~repro.serving.traffic.poisson_mixture` — and return
    the drained stats with per-model breakdowns."""
    session = ServingSession(backend=backend, arbiter=arbiter, seed=seed,
                             log=log, reject_infeasible=reject_infeasible,
                             memory_aware=memory_aware)
    for name, workload, policy in models:
        session.register(name, workload, policy=policy)
    session.duration = trace.duration
    for req in sorted(trace.requests, key=lambda r: r.arrival):
        session.submit(req)
    if drain:
        return session.drain()
    session.run_until(trace.duration)
    return session.stats()
