"""Online serving session: submit/stream front-end over the run-commit core.

The paper's premise is SLA-aware scheduling of a *live* request stream, but
the original front-end was offline: ``InferenceServer.run(trace)`` ingested
a pre-sorted arrival list and only returned stats after full drain. A
:class:`ServingSession` turns that inside out —

    session = ServingSession(policy, backend)
    h = session.submit(req, on_token=lambda h, t: ...)
    session.run_until(t)        # incremental clock advancement
    session.step()              # ... or one scheduling step at a time
    h.state                     # QUEUED → ADMITTED → RUNNING → DONE
    session.drain()             # finish everything -> ServeStats

while the scheduling core underneath is exactly the PR-2 run-commit loop:
the policy is consulted at every run boundary, commits a run of
consecutive node ids, and the backend executes it as one fused dispatch.
Requests can be submitted mid-flight, observed, rejected at admission
control, and given *per-request SLA classes* (``Request.sla``); both
execution substrates — the analytic ``SimExecutor`` (virtual time) and the
real ``JaxEngine`` (wall-clock time) — drive through the same
:class:`~repro.serving.backend.Backend` contract, so every scenario runs
unchanged on either.

Handle lifecycle
----------------
``QUEUED``   — submitted, waiting in the policy's InfQ (or in the
               session's future-arrivals queue when submitted ahead of its
               arrival time, e.g. trace replay);
``ADMITTED`` — the policy pulled it out of the InfQ into its batch state
               (``t_first_issue`` is set);
``REJECTED`` — refused at admission control (``reject_infeasible=True``
               and the request's own deadline is already unmeetable even
               if it ran alone immediately);
``RUNNING``  — a committed run containing the request has executed;
``DONE``     — finished; ``t_finish``/``latency``/``tokens`` are final.

Streaming
---------
At every run boundary the session asks the backend how many response
tokens each just-executed request has produced (decode megasteps already
hold the sampled tokens — the JAX engine surfaces them; the simulator
reports virtual tokens, one per completed decode cycle). New tokens fire
the handle's ``on_token(handle, token)`` callback, stamp
``t_first_token`` (TTFT), and accumulate in ``handle.tokens`` — for the
JAX backend these are bit-exact the batch ``execute_run`` results.

Compatibility
-------------
``run_trace(policy, backend, trace)`` replays an offline trace through a
session and returns the familiar :class:`ServeStats`;
``InferenceServer.run`` and ``run_policy`` are thin wrappers over it, so
every pre-existing experiment script and test runs unmodified.
"""
from __future__ import annotations

import heapq
import itertools
from enum import Enum
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.policies import Policy
from ..core.request import Request
from .backend import Backend, ServerLog, run_label
from .metrics import ServeStats
from .traffic import Trace


class HandleState(Enum):
    QUEUED = "queued"
    ADMITTED = "admitted"
    RUNNING = "running"
    DONE = "done"
    REJECTED = "rejected"


class RequestHandle:
    """Caller-facing view of one submitted request's lifecycle."""

    def __init__(self, req: Request, session: "ServingSession",
                 on_token: Optional[Callable] = None):
        self.request = req
        self.t_submit = session.now
        self.on_token = on_token
        self.tokens: List[int] = []     # streamed response tokens so far
        self._n_tokens = 0
        self._rejected = False
        self._running = False

    # ------------------------------------------------------------------
    @property
    def state(self) -> HandleState:
        """Derived, monotone lifecycle state (no per-step bookkeeping)."""
        if self._rejected:
            return HandleState.REJECTED
        r = self.request
        if r.done:
            return HandleState.DONE
        if self._running:
            return HandleState.RUNNING
        if r.t_first_issue is not None:
            return HandleState.ADMITTED
        return HandleState.QUEUED

    @property
    def done(self) -> bool:
        return self.state in (HandleState.DONE, HandleState.REJECTED)

    @property
    def t_first_token(self) -> Optional[float]:
        return self.request.t_first_token

    @property
    def t_finish(self) -> Optional[float]:
        return self.request.t_finish

    @property
    def latency(self) -> Optional[float]:
        r = self.request
        return None if r.t_finish is None else r.t_finish - r.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (from arrival)."""
        r = self.request
        return (None if r.t_first_token is None
                else r.t_first_token - r.arrival)

    def __repr__(self):
        return (f"RequestHandle(rid={self.request.rid}, "
                f"state={self.state.value}, tokens={len(self.tokens)})")


class ServingSession:
    """Online serving front-end over one (policy, backend) pair.

    ``reject_infeasible``: when the policy carries a slack predictor,
    refuse at submit time any request whose own deadline is unmeetable
    even running alone immediately (conservative single-input bound) —
    the handle goes straight to ``REJECTED`` instead of burning batch
    slack on a guaranteed violation. Off by default (the paper's system
    never drops work).

    ``seed`` feeds the RNG handed to ``Backend.prepare`` (the JAX engine
    samples synthetic prompts from it when none is supplied).
    """

    def __init__(self, policy: Policy, backend: Backend, *, seed: int = 0,
                 reject_infeasible: bool = False,
                 log: Optional[ServerLog] = None):
        self.policy = policy
        self.backend = backend
        self.log = log if log is not None else ServerLog()
        self.now = 0.0
        self.duration: Optional[float] = None    # reporting window override
        self.reject_infeasible = reject_infeasible
        self.handles: Dict[int, RequestHandle] = {}
        self._finished: Dict[int, Request] = {}   # rid-keyed: O(1) release
        self._rejected: Dict[int, Request] = {}
        self._rng = np.random.default_rng(seed)
        self._arrivals: list = []                # heap of (t, tiebreak, req)
        self._seq = itertools.count()
        self._classes: Dict[str, Optional[float]] = {}

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, req: Request, *, prompt_tokens=None,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Register a request with the session and return its handle.

        ``req.arrival`` in the future (relative to the session clock) is
        honored — the request enters the policy's InfQ when the clock
        reaches it (trace replay); an arrival in the past is clamped to
        *now* (live submission — waiting time, slack, and latency all
        count from the submission instant, not a stale timestamp).
        ``on_token(handle, token)`` fires once per response token at the
        producing run's boundary.
        """
        assert req.rid not in self.handles, f"rid {req.rid} already submitted"
        req.arrival = max(req.arrival, self.now)
        handle = RequestHandle(req, self, on_token=on_token)
        self.handles[req.rid] = handle
        deadline = req.sla.deadline if req.sla else None
        prev = self._classes.setdefault(req.sla_name, deadline)
        assert prev == deadline, (
            f"SLA class {req.sla_name!r} submitted with deadline {deadline} "
            f"but previously seen with {prev} — per-class reporting needs "
            f"one deadline per class name")
        if self.reject_infeasible and self._infeasible(req):
            handle._rejected = True
            self._rejected[req.rid] = req
            # the feasibility probe may have memoized predictor state for a
            # request the policy will never see finish — release it here
            self.policy.request_finished([req])
            return handle
        self.backend.prepare(req, self._rng, prompt_tokens=prompt_tokens)
        heapq.heappush(self._arrivals,
                       (req.arrival, next(self._seq), req))
        return handle

    def _infeasible(self, req: Request) -> bool:
        # arrival is already clamped to the session clock, so the deadline
        # window opens now: unmeetable iff even an isolated immediate run
        # (the conservative single-input bound) overshoots it
        pred = getattr(self.policy, "predictor", None)
        if pred is None or not hasattr(pred, "single_total"):
            return False
        return pred.single_total(req) > pred.deadline(req)

    # ------------------------------------------------------------------
    # Clock advancement
    # ------------------------------------------------------------------
    def _enqueue_due(self):
        while self._arrivals and self._arrivals[0][0] <= self.now + 1e-12:
            _, _, req = heapq.heappop(self._arrivals)
            self.policy.enqueue(req, self.now)

    def step(self, limit: Optional[float] = None) -> bool:
        """One scheduling step: enqueue due arrivals, then either execute
        the policy's next committed run (clock advances by its latency) or
        jump the clock to the next event (arrival / policy timer). Returns
        ``False`` when fully idle — nothing queued, running, or pending —
        or when the next event lies beyond ``limit``."""
        self._enqueue_due()
        work = self.policy.next_work(self.now)
        if work is None:
            candidates = []
            if self._arrivals:
                candidates.append(self._arrivals[0][0])
            t = self.policy.next_timer(self.now)
            if t is not None:
                candidates.append(max(t, self.now))
            if not candidates:
                return False                      # fully drained
            target = min(candidates)
            if limit is not None and target > limit:
                self.now = max(self.now, limit)
                return False
            self.now = target
            return True

        sb, run = work
        reqs = list(sb.live_requests)
        latency, per_node = self.backend.execute_run(sb, run)
        self.log.nodes_executed += len(run)
        self.log.runs_executed += 1
        self.log.busy_time += latency
        self.log.batch_size_sum += sb.size * len(run)
        if per_node is not None:
            for nid, lat in zip(run, per_node):
                self.log.record(nid, lat)
        else:
            self.log.record(run_label(run), latency, n=len(run))
        self.now += latency
        done_now = self.policy.work_done(sb, self.now, len(run))
        # observe (stream tokens, stamp TTFT) BEFORE the completion hooks:
        # backends may release per-request device resources there
        for r in reqs:
            self._observe(r)
        if done_now:
            self.backend.on_finished(done_now)
            self.policy.request_finished(done_now)
        for r in done_now:
            self._finished[r.rid] = r
        return True

    def _observe(self, req: Request):
        """Run-boundary bookkeeping for one just-executed request: state
        transition to RUNNING, TTFT stamp, token streaming."""
        handle = self.handles.get(req.rid)
        if handle is None:
            return
        handle._running = True
        n = self.backend.token_count(req)
        if n <= handle._n_tokens:
            return
        if req.t_first_token is None:
            req.t_first_token = self.now
        toks = self.backend.tokens(req)
        new = (list(toks[handle._n_tokens:n]) if toks is not None
               else [-1] * (n - handle._n_tokens))   # virtual tokens (sim)
        handle._n_tokens = n
        handle.tokens.extend(new)
        if handle.on_token is not None:
            for t in new:
                handle.on_token(handle, t)

    def run_until(self, t: float) -> float:
        """Advance the session clock to (at least) ``t``, executing every
        run that *starts* at or before ``t`` — a run in flight at the
        boundary completes (the clock only advances at run boundaries).
        Returns the clock."""
        while self.now <= t:
            if not self.step(limit=t):
                break
        self.now = max(self.now, t)
        return self.now

    def drain(self) -> ServeStats:
        """Run everything outstanding to completion and return stats."""
        while self.step():
            pass
        return self.stats()

    def release(self, handle: RequestHandle) -> None:
        """Drop a finished/rejected handle's per-request state from the
        session (long-lived online sessions otherwise accumulate every
        handle, request, and token list ever submitted). The request no
        longer contributes to :meth:`stats`; releasing a live request is
        refused."""
        assert handle.done, "cannot release a live request"
        req = handle.request
        self.handles.pop(req.rid, None)
        self._finished.pop(req.rid, None)
        self._rejected.pop(req.rid, None)
        self.backend.release_request(req)

    # ------------------------------------------------------------------
    @property
    def outstanding(self) -> int:
        return len(self._arrivals) + self.policy.outstanding

    @property
    def finished(self) -> List[Request]:
        return list(self._finished.values())

    @property
    def rejected(self) -> List[Request]:
        return list(self._rejected.values())

    def stats(self) -> ServeStats:
        duration = self.duration if self.duration is not None else self.now
        return ServeStats(policy=self.policy.name, duration=duration,
                          finished=list(self._finished.values()),
                          rejected=len(self._rejected),
                          classes=dict(self._classes))


def run_trace(policy: Policy, backend: Backend, trace: Trace, *,
              drain: bool = True, seed: int = 0,
              log: Optional[ServerLog] = None,
              reject_infeasible: bool = False) -> ServeStats:
    """Offline-compatibility wrapper: replay a whole trace through a
    :class:`ServingSession` and return its :class:`ServeStats` — the
    ``InferenceServer.run(trace)`` contract, now a thin shim."""
    session = ServingSession(policy, backend, seed=seed, log=log,
                             reject_infeasible=reject_infeasible)
    session.duration = trace.duration
    for req in sorted(trace.requests, key=lambda r: r.arrival):
        session.submit(req)
    if drain:
        return session.drain()
    session.run_until(trace.duration)
    return session.stats()
