"""Serving metrics: latency distribution, throughput, SLA satisfaction."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..core.request import Request


@dataclass
class ServeStats:
    policy: str
    duration: float
    finished: List[Request] = field(default_factory=list)

    @property
    def latencies(self) -> np.ndarray:
        return np.array([r.latency() for r in self.finished])

    @property
    def avg_latency(self) -> float:
        lat = self.latencies
        return float(lat.mean()) if len(lat) else float("nan")

    def percentile(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    @property
    def makespan(self) -> float:
        if not self.finished:
            return self.duration
        return max(r.t_finish for r in self.finished)

    @property
    def throughput(self) -> float:
        """Completed requests per second over the busy window (arrival span
        + drain) — policies that stall requests pay for the longer drain."""
        return len(self.finished) / max(self.duration, self.makespan)

    def sla_violation_rate(self, sla: float) -> float:
        lat = self.latencies
        if not len(lat):
            return float("nan")
        return float((lat > sla).mean())

    def summary(self, sla: Optional[float] = None) -> Dict[str, float]:
        out = {
            "policy": self.policy,
            "completed": len(self.finished),
            "avg_latency_ms": self.avg_latency * 1e3,
            "p25_ms": self.percentile(25) * 1e3,
            "p75_ms": self.percentile(75) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
            "throughput_rps": self.throughput,
        }
        if sla is not None:
            out["sla_violation_rate"] = self.sla_violation_rate(sla)
        return out
