"""Serving metrics: latency distribution, throughput, SLA satisfaction.

Per-SLA-class reporting: every request carries a class name (``"default"``
when it has no :class:`~repro.core.request.SLAClass`), and a finished
session records the classes it saw (name -> deadline, ``None`` for the
default class, whose deadline is supplied at ``summary(sla=...)`` time).

Per-model reporting: requests routed through a
:class:`~repro.serving.registry.ModelRegistry` carry a model tag
(untagged requests fall back to their workload's name), and the session
records the registered models (name -> policy name) so a model with zero
finishers still appears, NaN-safe, in :meth:`ServeStats.per_model`.
Aggregate *attainment* across mixed SLA classes judges every request
against its **own** deadline (class deadline, else the supplied default).

SLA accounting judges every SUBMITTED request: a request rejected at
admission control counts as a violation of its own class deadline (the
paper's SLA-satisfaction figures count all submitted requests — without
this a policy could inflate attainment by rejecting aggressively). The
same rule covers every *dropped* disposition of the failure model —
cancelled, expired, failed (fault retries exhausted), shed — none ever
produced a response by any deadline, so cancellation/shedding can only
raise attainment by rescuing OTHER requests, never by hiding its
victims. Latency/TTFT/TPOT/throughput remain finished-only by
construction.

All aggregates are NaN-safe when a slice has no finishers. TTFT/TPOT need
``t_first_token``, which only the session front-end stamps (at the run
boundary emitting token #1) — trace replays through
``run_trace``/``InferenceServer.run`` get it for free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..core.request import Request

_NAN = float("nan")


def _mean(xs: List[float]) -> float:
    return float(np.mean(xs)) if xs else _NAN


def _percentile(reqs: List[Request], q: float) -> float:
    if not reqs:
        return _NAN
    return float(np.percentile([r.latency() for r in reqs], q))


@dataclass
class ServeStats:
    policy: str
    duration: float
    finished: List[Request] = field(default_factory=list)
    rejected: int = 0                       # refused at admission control
    # the rejected requests themselves: SLA accounting counts every
    # SUBMITTED request (paper Fig. SLA-satisfaction), so a rejection is a
    # violation of its class deadline — a policy cannot inflate attainment
    # by rejecting aggressively
    rejected_requests: List[Request] = field(default_factory=list)
    # failure-model terminal dispositions (see serving.session): all are
    # SLA violations of their own class deadline, like rejections
    cancelled_requests: List[Request] = field(default_factory=list)
    expired_requests: List[Request] = field(default_factory=list)
    failed_requests: List[Request] = field(default_factory=list)
    shed_requests: List[Request] = field(default_factory=list)
    retried: int = 0                        # fault-retry requeue events
    # SLA classes observed at submission: name -> relative deadline
    # (None for the default class — its target arrives via summary(sla=...))
    classes: Dict[str, Optional[float]] = field(default_factory=dict)
    # registered models: name -> policy name (empty for pre-registry stats)
    models: Dict[str, str] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def of_class(self, name: Optional[str] = None) -> List[Request]:
        if name is None:
            return self.finished
        return [r for r in self.finished if r.sla_name == name]

    def of_model(self, name: Optional[str] = None) -> List[Request]:
        if name is None:
            return self.finished
        return [r for r in self.finished if r.model_name == name]

    def rejected_of_class(self, name: Optional[str] = None) -> List[Request]:
        if name is None:
            return self.rejected_requests
        return [r for r in self.rejected_requests if r.sla_name == name]

    def rejected_of_model(self, name: Optional[str] = None) -> List[Request]:
        if name is None:
            return self.rejected_requests
        return [r for r in self.rejected_requests if r.model_name == name]

    @property
    def dropped_requests(self) -> List[Request]:
        """Every request removed from service without a response:
        cancelled + expired + failed + shed (rejections are reported
        separately — they never entered service at all)."""
        return (self.cancelled_requests + self.expired_requests
                + self.failed_requests + self.shed_requests)

    def dropped_of_class(self, name: Optional[str] = None) -> List[Request]:
        if name is None:
            return self.dropped_requests
        return [r for r in self.dropped_requests if r.sla_name == name]

    def dropped_of_model(self, name: Optional[str] = None) -> List[Request]:
        if name is None:
            return self.dropped_requests
        return [r for r in self.dropped_requests if r.model_name == name]

    @property
    def latencies(self) -> np.ndarray:
        return np.array([r.latency() for r in self.finished])

    @property
    def avg_latency(self) -> float:
        lat = self.latencies
        return float(lat.mean()) if len(lat) else _NAN

    def percentile(self, q: float, cls: Optional[str] = None) -> float:
        return _percentile(self.of_class(cls), q)

    @property
    def makespan(self) -> float:
        if not self.finished:
            return self.duration
        return max(r.t_finish for r in self.finished)

    @property
    def throughput(self) -> float:
        """Completed requests per second over the busy window (arrival span
        + drain) — policies that stall requests pay for the longer drain."""
        return len(self.finished) / max(self.duration, self.makespan)

    # ------------------------------------------------------------------
    def sla_violation_rate(self, sla: float,
                           cls: Optional[str] = None) -> float:
        """Fraction of SUBMITTED requests (finished + rejected + dropped)
        of the class missing ``sla``; every rejection and every dropped
        disposition (cancelled/expired/failed/shed) is a violation — it
        never produced a response by any deadline. NaN when the class saw
        no submissions at all (an all-refused class reports 1.0)."""
        reqs = self.of_class(cls)
        n_rej = (len(self.rejected_of_class(cls))
                 + len(self.dropped_of_class(cls)))
        if not reqs and not n_rej:
            return _NAN
        viol = n_rej
        if reqs:
            lat = np.array([r.latency() for r in reqs])
            viol += int((lat > sla).sum())
        return viol / (len(reqs) + n_rej)

    def sla_attainment(self, sla: float, cls: Optional[str] = None) -> float:
        v = self.sla_violation_rate(sla, cls)
        return _NAN if np.isnan(v) else 1.0 - v

    def _deadline_of(self, req: Request,
                     default_sla: Optional[float]) -> Optional[float]:
        """The deadline ``req`` is judged against: its own SLA class, else
        its class's recorded deadline, else the supplied default."""
        if req.sla is not None:
            return req.sla.deadline
        return self._class_deadline(req.sla_name, default_sla)

    def attainment(self, sla: Optional[float] = None,
                   model: Optional[str] = None) -> float:
        """Aggregate SLA attainment with per-request deadlines: the
        fraction of SUBMITTED requests (finished **and rejected** — the
        paper's SLA-satisfaction counts everything submitted) meeting
        their *own* class deadline (``sla`` supplies the default
        class's). Mixed-tier and multi-model runs are judged fairly — a
        request is never held to another tier's target; every rejection
        with a deadline counts as a miss. NaN when no submission has a
        deadline."""
        judged = [(r.latency() <= d)
                  for r in self.of_model(model)
                  for d in [self._deadline_of(r, sla)] if d is not None]
        judged += [False
                   for r in (self.rejected_of_model(model)
                             + self.dropped_of_model(model))
                   if self._deadline_of(r, sla) is not None]
        return _mean([float(ok) for ok in judged])

    def ttft(self, cls: Optional[str] = None) -> float:
        """Mean time-to-first-token (seconds from arrival; session-stamped)."""
        return _mean([r.t_first_token - r.arrival for r in self.of_class(cls)
                      if r.t_first_token is not None])

    def tpot(self, cls: Optional[str] = None) -> float:
        """Mean time-per-output-token over the decode phase (first token ->
        finish, across the remaining n_tokens - 1 tokens)."""
        return _mean([(r.t_finish - r.t_first_token) / (r.n_tokens - 1)
                      for r in self.of_class(cls)
                      if r.t_first_token is not None and r.n_tokens >= 2])

    def _class_deadline(self, name: str,
                        default_sla: Optional[float]) -> Optional[float]:
        d = self.classes.get(name)
        return default_sla if d is None else d

    def per_class(self, sla: Optional[float] = None
                  ) -> Dict[str, Dict[str, float]]:
        """Per-SLA-class breakdown: completion count, attainment/violation
        against the class's own deadline, p50/p99, TTFT, TPOT. ``sla``
        supplies the default class's deadline. NaN-safe throughout."""
        names = (set(self.classes) | {r.sla_name for r in self.finished}
                 | {r.sla_name for r in self.rejected_requests}
                 | {r.sla_name for r in self.dropped_requests})
        out: Dict[str, Dict[str, float]] = {}
        for name in sorted(names):
            deadline = self._class_deadline(name, sla)
            viol = (self.sla_violation_rate(deadline, name)
                    if deadline is not None else _NAN)
            out[name] = {
                "completed": len(self.of_class(name)),
                "rejected": len(self.rejected_of_class(name)),
                "cancelled": len([r for r in self.cancelled_requests
                                  if r.sla_name == name]),
                "expired": len([r for r in self.expired_requests
                                if r.sla_name == name]),
                "failed": len([r for r in self.failed_requests
                               if r.sla_name == name]),
                "shed": len([r for r in self.shed_requests
                             if r.sla_name == name]),
                "deadline_ms": (deadline * 1e3 if deadline is not None
                                else _NAN),
                "sla_violation_rate": viol,
                "sla_attainment": (_NAN if np.isnan(viol) else 1.0 - viol),
                "p50_ms": self.percentile(50, name) * 1e3,
                "p95_ms": self.percentile(95, name) * 1e3,
                "p99_ms": self.percentile(99, name) * 1e3,
                "ttft_ms": self.ttft(name) * 1e3,
                "tpot_ms": self.tpot(name) * 1e3,
            }
        return out

    def per_model(self, sla: Optional[float] = None
                  ) -> Dict[str, Dict[str, float]]:
        """Per-model breakdown across the registry: completion count,
        attainment against each request's *own* SLA-class deadline
        (``sla`` = default class target), p50/p99 latency, TTFT, TPOT.
        Registered models with no finishers appear with NaN rows."""
        names = (set(self.models) | {r.model_name for r in self.finished}
                 | {r.model_name for r in self.rejected_requests}
                 | {r.model_name for r in self.dropped_requests})
        out: Dict[str, Dict[str, float]] = {}
        for name in sorted(names):
            reqs = self.of_model(name)
            att = self.attainment(sla, model=name)
            out[name] = {
                "completed": len(reqs),
                "rejected": len(self.rejected_of_model(name)),
                "cancelled": len([r for r in self.cancelled_requests
                                  if r.model_name == name]),
                "expired": len([r for r in self.expired_requests
                                if r.model_name == name]),
                "failed": len([r for r in self.failed_requests
                               if r.model_name == name]),
                "shed": len([r for r in self.shed_requests
                             if r.model_name == name]),
                "sla_attainment": att,
                "sla_violation_rate": (_NAN if np.isnan(att) else 1.0 - att),
                "p50_ms": _percentile(reqs, 50) * 1e3,
                "p95_ms": _percentile(reqs, 95) * 1e3,
                "p99_ms": _percentile(reqs, 99) * 1e3,
                "ttft_ms": _mean([r.t_first_token - r.arrival for r in reqs
                                  if r.t_first_token is not None]) * 1e3,
                "tpot_ms": _mean(
                    [(r.t_finish - r.t_first_token) / (r.n_tokens - 1)
                     for r in reqs
                     if r.t_first_token is not None and r.n_tokens >= 2])
                    * 1e3,
            }
        return out

    # ------------------------------------------------------------------
    def summary(self, sla: Optional[float] = None) -> Dict[str, float]:
        out = {
            "policy": self.policy,
            "completed": len(self.finished),
            "avg_latency_ms": self.avg_latency * 1e3,
            "p25_ms": self.percentile(25) * 1e3,
            "p50_ms": self.percentile(50) * 1e3,
            "p75_ms": self.percentile(75) * 1e3,
            "p95_ms": self.percentile(95) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
            "throughput_rps": self.throughput,
        }
        if self.rejected:
            out["rejected"] = self.rejected
        # failure-model dispositions only appear when they happened, so a
        # fault-free run's summary dict is byte-identical to before
        for key, reqs in (("cancelled", self.cancelled_requests),
                          ("expired", self.expired_requests),
                          ("failed", self.failed_requests),
                          ("shed", self.shed_requests)):
            if reqs:
                out[key] = len(reqs)
        if self.retried:
            out["retried"] = self.retried
        if sla is not None:
            out["sla_violation_rate"] = self.sla_violation_rate(sla)
        # per-class violation rates (only meaningful keys: a class needs a
        # deadline from its SLAClass or the summary's sla argument)
        for name, row in self.per_class(sla).items():
            if name == "default" and len(self.classes) <= 1:
                continue                         # single-tier: no breakdown
            if not np.isnan(row["deadline_ms"]):
                out[f"sla_viol[{name}]"] = row["sla_violation_rate"]
        # per-model breakdown only for genuinely multi-tenant runs
        if len(self.models) > 1 or len({r.model_name
                                        for r in self.finished}) > 1:
            for name, row in self.per_model(sla).items():
                out[f"sla_viol[model:{name}]"] = row["sla_violation_rate"]
                out[f"p99_ms[model:{name}]"] = row["p99_ms"]
        return out
