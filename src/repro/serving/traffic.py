"""Inference query traffic generation (paper §V).

Poisson arrivals per the MLPerf cloud-inference methodology; rate buckets
low/medium/high = 0-256 / 256-500 / 500+ queries/sec. Also supports a
bursty MMPP-style generator (beyond-paper robustness studies) and
multi-model traces for the co-location experiment (§VI-C):
:func:`poisson_mixture` superposes per-model Poisson processes with
**independent, name-keyed RNG streams** — registering an extra model (or
reordering the mixture) never perturbs another model's sampled arrivals
or lengths — and tags each request with its registry ``model`` name so
``ServingSession.submit`` routes it without an explicit argument.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.request import Request, SLAClass
from .workload import Workload


@dataclass
class Trace:
    """Arrival-sorted list of requests (each optionally ``model``-tagged)."""
    requests: List[Request]
    duration: float

    def __len__(self):
        return len(self.requests)

    @property
    def models(self) -> Tuple[str, ...]:
        """Distinct model tags present, sorted (empty for untagged traces)."""
        return tuple(sorted({r.model for r in self.requests
                             if r.model is not None}))

    def fresh(self) -> "Trace":
        """Unexecuted copy — required when replaying one trace across
        several policies (request state is mutated by a run)."""
        return Trace([r.clone() for r in self.requests], self.duration)


def poisson_trace(wl: Workload, rate: float, duration: float,
                  seed: int = 0, model: Optional[str] = None) -> Trace:
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            break
        req = wl.sample_request(rng, t)
        req.model = model
        reqs.append(req)
    return Trace(reqs, duration)


def _stream_key(name: str) -> int:
    """Stable per-model RNG stream key (CRC32 of the model name — NOT
    ``hash()``, which is salted per process)."""
    return zlib.crc32(name.encode("utf-8"))


def poisson_mixture(models: Sequence[Tuple[str, Workload, float]],
                    duration: float, seed: int = 0) -> Trace:
    """Superposition of per-model Poisson processes for multi-tenant
    serving: ``models`` is a sequence of ``(name, workload, rate)``
    triples; each request is tagged with its model ``name``.

    Each model draws from its own RNG stream seeded by ``(seed,
    crc32(name))``, so a model's arrivals and sampled prompt/decode
    lengths are a pure function of (seed, name, rate, duration) — adding,
    removing, or reordering other mixture components cannot perturb them
    (determinism across experiment grids). Ties in arrival time keep the
    mixture's listing order (stable sort)."""
    names = [name for name, _, _ in models]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate model names in mixture: {names}")
    reqs: List[Request] = []
    for name, wl, rate in models:
        if rate <= 0:
            raise ValueError(
                f"model {name!r} has non-positive rate {rate}")
        rng = np.random.default_rng([seed, _stream_key(name)])
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= duration:
                break
            req = wl.sample_request(rng, t)
            req.model = name
            reqs.append(req)
    reqs.sort(key=lambda r: r.arrival)
    return Trace(reqs, duration)


def bursty_trace(wl: Workload, rate_low: float, rate_high: float,
                 switch_period: float, duration: float, seed: int = 0) -> Trace:
    """Two-state MMPP: alternates between low/high Poisson rates."""
    rng = np.random.default_rng(seed)
    t, reqs, high = 0.0, [], False
    next_switch = switch_period
    while t < duration:
        rate = rate_high if high else rate_low
        t += rng.exponential(1.0 / rate)
        if t >= next_switch:
            high = not high
            next_switch += switch_period
        if t < duration:
            reqs.append(wl.sample_request(rng, t))
    return Trace(reqs, duration)


def colocated_trace(workloads: Sequence[Workload], rates: Sequence[float],
                    duration: float, seed: int = 0) -> Trace:
    """Superposition of per-model Poisson processes (co-location, §VI-C)."""
    reqs: List[Request] = []
    for i, (wl, rate) in enumerate(zip(workloads, rates)):
        reqs.extend(poisson_trace(wl, rate, duration, seed=seed + i).requests)
    reqs.sort(key=lambda r: r.arrival)
    return Trace(reqs, duration)


def with_sla_classes(trace: Trace, classes: Sequence[SLAClass],
                     probs: Optional[Sequence[float]] = None,
                     seed: int = 0) -> Trace:
    """Assign per-request SLA classes i.i.d. across a trace (mixed-tier
    serving): each request draws one of ``classes`` with the given
    probabilities (uniform when omitted). Mutates and returns ``trace``;
    ``Trace.fresh()`` clones preserve the assignment."""
    rng = np.random.default_rng(seed)
    p = None if probs is None else list(probs)
    idx = rng.choice(len(classes), size=len(trace.requests), p=p)
    for r, i in zip(trace.requests, idx):
        r.sla = classes[int(i)]
    return trace
