"""Gateway middleware: backpressure, fate mapping, per-request timeouts.

The middleware stack sits between the HTTP layer and the
:class:`~repro.serving.session.ServingSession`:

  * **Bounded ingress / backpressure** — the gateway refuses work with
    ``429 Too Many Requests`` + ``Retry-After`` *before* submitting it,
    when either its own in-flight budget (``max_inflight``) or the
    session's queue/memory budget is exhausted. Refusing at the door is
    deliberately distinct from the session's own load shedding: a 429'd
    request never enters the scheduler (cheap, retryable by the
    client), while a SHED fate means admitted work was sacrificed
    (503). High-``shed_priority`` requests keep a reserved headroom
    above the soft bound so an interactive tier can still get in while
    bulk traffic is being turned away — the per-request
    ``shed_priority`` (defaulting to the model's registered priority)
    is honored at the door exactly like the session honors it in the
    shedder.
  * **Fate -> HTTP status** — every terminal
    :class:`~repro.serving.session.HandleState` maps to one status
    (:data:`FATE_STATUS`); mid-stream fates arrive as a final SSE
    ``error`` event instead, carrying the same status number.
  * **Per-request timeout** — a :class:`TimeoutBudget` caps the
    wall-clock an exchange may take; expiry cancels the handle
    (``handle.cancel()`` frees its KV slot immediately) and reports
    ``408`` (or a terminal SSE event when streaming already began).
"""
from __future__ import annotations

from typing import Dict, Optional

#: Terminal handle fate -> HTTP status. Distinct statuses per fate so a
#: client (and the load generator's error accounting) can tell refusal
#: modes apart without parsing bodies:
#:
#:   done      -> 200  (completed; SSE stream closed with a `done` event)
#:   rejected  -> 422  (admission control: the deadline is provably
#:                      unmeetable — retrying immediately cannot help)
#:   shed      -> 503  (load shedding sacrificed admitted work; Retry-After
#:                      is attached — capacity should recover)
#:   expired   -> 504  (deadline provably blown mid-flight; reaped)
#:   failed    -> 502  (backend fault, retry budget exhausted)
#:   cancelled -> 499  (client closed the request; never sent on the wire,
#:                      log-only — the nginx convention)
#:
#: Gateway-level refusals use 429 (bounded ingress, never submitted) and
#: 408 (per-request timeout, handle cancelled) — those are not fates.
FATE_STATUS: Dict[str, int] = {
    "done": 200,
    "rejected": 422,
    "shed": 503,
    "expired": 504,
    "failed": 502,
    "cancelled": 499,
}

#: Statuses on which a Retry-After hint is attached.
RETRYABLE_STATUSES = frozenset({429, 503})

STATUS_REASONS = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 408: "Request Timeout",
    413: "Payload Too Large", 422: "Unprocessable Entity",
    429: "Too Many Requests", 499: "Client Closed Request",
    500: "Internal Server Error", 502: "Bad Gateway",
    503: "Service Unavailable", 504: "Gateway Timeout",
}


def status_for_state(state) -> int:
    """HTTP status for a terminal ``HandleState`` (its ``value`` is the
    lifecycle fate string; DONE maps through ``"done"``)."""
    return FATE_STATUS[state.value]


class Backpressure:
    """Bounded-ingress admission at the gateway door.

    ``check(model, shed_priority)`` returns ``None`` to admit, or a
    ``Retry-After`` hint in wall seconds to refuse with 429. Refusal
    triggers when

      * the gateway's in-flight budget is full — ``max_inflight`` live
        exchanges (soft bound; requests at the session's *protected*
        shed priority may run ``headroom`` past it so an interactive
        tier is not starved by bulk arrivals already in the house), or
      * the session's own ingress is saturated: its bounded queue
        (``max_queue``) is at capacity, or memory-aware admission
        reports zero free-slot room for the model with a backlog
        already waiting (every new submission would join a queue the
        device cannot drain yet).

    The Retry-After hint scales with the backlog over the observed
    completion rate (the driver's rolling throughput estimate), clamped
    to ``[min_hint, max_hint]`` — a loaded gateway asks clients to back
    off longer, an idle one barely at all.
    """

    def __init__(self, driver, *, max_inflight: Optional[int] = None,
                 headroom: Optional[int] = None,
                 retry_after: float = 0.5,
                 min_hint: float = 0.05, max_hint: float = 5.0):
        self.driver = driver
        self.max_inflight = max_inflight
        self.headroom = (headroom if headroom is not None
                         else max(8, (max_inflight or 0) // 8))
        self.retry_after = retry_after
        self.min_hint = min_hint
        self.max_hint = max_hint

    # ------------------------------------------------------------------
    def _hint(self, backlog: int) -> float:
        rate = self.driver.completion_rate()
        if rate > 0.0:
            return min(self.max_hint,
                       max(self.min_hint, backlog / rate))
        return self.retry_after

    def check(self, model: str, shed_priority: int) -> Optional[float]:
        session = self.driver.session
        inflight = self.driver.inflight
        if self.max_inflight is not None:
            bound = self.max_inflight
            if shed_priority >= self.driver.protected_priority():
                bound += self.headroom
            if inflight >= bound:
                return self._hint(inflight)
        depth = sum(len(e.policy.queue)
                    for e in session.registry.entries())
        if session.max_queue is not None and depth >= session.max_queue:
            return self._hint(depth)
        if session.memory_aware and depth > 0:
            if self.driver.mem_room(model) == 0:
                return self._hint(depth)
        return None


class TimeoutBudget:
    """Wall-clock budget for one HTTP exchange. ``remaining()`` feeds
    each successive ``wait_for`` so the *total* exchange time is capped,
    not each individual event gap."""

    def __init__(self, clock, timeout_s: float):
        self._clock = clock              # wall-clock callable (loop.time)
        self.timeout_s = float(timeout_s)
        self.t0 = clock()

    def remaining(self) -> float:
        return self.timeout_s - (self._clock() - self.t0)

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0.0
