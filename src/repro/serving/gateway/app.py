"""GatewayApp: routes, request lifecycle, and graceful shutdown.

The gateway is a single-threaded asyncio application around one
:class:`~repro.serving.gateway.bridge.SessionDriver`:

  * ``POST /v1/generate`` — submit one request, stream its tokens back
    as SSE (``token`` events, then ``done`` or ``error``). The JSON body
    selects ``model``, ``sla_class``/``deadline``, ``prompt_len``/
    ``decode_len`` (sampled from the model's workload when omitted) and
    ``shed_priority`` (defaults to the model's registered priority —
    used by the bounded-ingress door, see middleware).
  * ``GET /metrics`` — Prometheus text exposition (gauges re-sampled at
    scrape time).
  * ``GET /healthz`` — liveness (always 200 while the process runs).
  * ``GET /readyz`` — readiness: 200 only once serving and not
    draining, so load generators and orchestrators can gate on it.

Shutdown (SIGTERM/SIGINT) is a *drain*, not an abort: stop accepting,
flip ``/readyz`` to 503, run ``session.drain()`` so every admitted
request reaches a terminal fate (handlers observe their ``end`` events
and finish their streams), then report the drained stats and leak
check in a final ``drain`` log record.
"""
from __future__ import annotations

import asyncio
import signal
from typing import Dict, Optional, Set

from . import http
from .bridge import EV_END, EV_TOKEN, SessionDriver
from .middleware import (RETRYABLE_STATUSES, Backpressure, TimeoutBudget,
                         status_for_state)
from .sanitizer import LoopStallSanitizer
from .telemetry import AccessLog, GatewayMetrics, request_id

#: Status used for client-closed-request accounting (log-only; never
#: sent on the wire — the client is gone).
CLIENT_CLOSED = 499


class GatewayApp:
    """One serving gateway: HTTP front-end + driver + middleware."""

    def __init__(self, session, *, host: str = "127.0.0.1",
                 port: int = 0, time_scale: float = 1.0,
                 tick: float = 0.002,
                 request_timeout: Optional[float] = None,
                 max_inflight: Optional[int] = None,
                 metrics_log_interval: Optional[float] = None,
                 default_sla: Optional[float] = None,
                 deadline_by_class: Optional[Dict[str, float]] = None,
                 seed: int = 0, drain_grace: float = 5.0,
                 stall_interval: float = 0.005,
                 stall_threshold: float = 0.25,
                 log_stream=None, log_enabled: bool = True):
        self.session = session
        self.host = host
        # written once more in start() (ephemeral-port resolution),
        # before any handler can exist — the startup path is the only
        # writer, so the read-bind-write span there cannot interleave
        self.port = port                     # reprolint: owner=startup
        self.request_timeout = request_timeout
        self.drain_grace = drain_grace
        self.deadline_by_class = dict(deadline_by_class or {})
        self.access_log = AccessLog(stream=log_stream, enabled=log_enabled)
        self.metrics = GatewayMetrics(
            default_sla=default_sla,
            deadline_by_class=self.deadline_by_class)
        self.driver = SessionDriver(
            session, time_scale=time_scale, tick=tick,
            metrics=self.metrics, access_log=self.access_log,
            metrics_log_interval=metrics_log_interval, seed=seed)
        self.backpressure = Backpressure(self.driver,
                                         max_inflight=max_inflight)
        self.sanitizer = LoopStallSanitizer(interval=stall_interval,
                                            threshold=stall_threshold)
        self.ready = False
        self.draining = False
        self.drained_stats = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._handlers: Set[asyncio.Task] = set()
        self._shutdown = asyncio.Event()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> None:
        self.driver.start()
        self.sanitizer.start()
        self._pump_task = asyncio.create_task(self.driver.pump())
        self._server = await asyncio.start_server(
            self._on_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self.ready = True
        self.access_log.emit("ready", host=self.host, port=self.port,
                             models=[e.name for e in
                                     self.session.registry.entries()])

    def request_shutdown(self) -> None:
        self._shutdown.set()

    def install_signal_handlers(self) -> None:
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGTERM, signal.SIGINT):
            loop.add_signal_handler(sig, self.request_shutdown)

    async def run(self) -> None:
        """Serve until a shutdown request, then drain."""
        await self.start()
        self.install_signal_handlers()
        await self._shutdown.wait()
        await self.drain()

    async def drain(self):
        """Graceful shutdown: refuse new work, run everything admitted
        to a terminal fate, let handlers flush, report."""
        self.draining = True
        self.ready = False
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        stats = self.driver.drain()          # pushes every end event
        if self._handlers:
            await asyncio.wait(set(self._handlers),
                               timeout=self.drain_grace)
        if self._pump_task is not None:
            # cancel-and-reap: absorb the CancelledError we caused so
            # the pump cannot outlive the drain or die unobserved; the
            # handle is swapped out BEFORE the suspension so the
            # shared field never spans the await
            pump, self._pump_task = self._pump_task, None
            pump.cancel()
            try:
                await pump
            except asyncio.CancelledError:
                pass
        await self.sanitizer.stop()
        self.drained_stats = stats
        mem = self.session.backend.memory_stats()
        self.access_log.emit(
            "drain", completed=self.driver.completed,
            outstanding=self.driver.inflight,
            slots_live=mem.slots_live,
            loop=self.sanitizer.stats.as_dict(),
            summary=stats.summary())
        return stats

    # ------------------------------------------------------------------
    # connection handling
    # ------------------------------------------------------------------
    async def _on_connection(self, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        self._handlers.add(task)
        try:
            await self._serve_one(reader, writer)
        except ConnectionError:
            pass                             # peer vanished mid-response
        finally:
            self._handlers.discard(task)
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _serve_one(self, reader, writer) -> None:
        try:
            req = await http.read_request(reader)
        except http.BadRequest as exc:
            await http.send_json(writer, 400, {"error": str(exc)})
            return
        if req is None:                      # EOF before any request
            return
        route = (req.method, req.path)
        if route == ("GET", "/healthz"):
            await http.send_json(writer, 200, {"status": "ok"})
        elif route == ("GET", "/readyz"):
            if self.ready and not self.draining:
                await http.send_json(writer, 200, {"status": "ready"})
            else:
                await http.send_json(
                    writer, 503,
                    {"status": "draining" if self.draining
                     else "starting"})
        elif route == ("GET", "/metrics"):
            self.metrics.sample_session(self.session)
            self.metrics.sample_loop(self.sanitizer)
            body = self.metrics.expose().encode("utf-8")
            await http.send_response(
                writer, 200, body,
                content_type="text/plain; version=0.0.4; charset=utf-8")
        elif route == ("POST", "/v1/generate"):
            await self._generate(req, reader, writer)
        elif req.path in ("/healthz", "/readyz", "/metrics",
                          "/v1/generate"):
            await http.send_json(writer, 405,
                                 {"error": f"{req.method} not allowed"})
        else:
            await http.send_json(writer, 404,
                                 {"error": f"no route {req.path}"})

    # ------------------------------------------------------------------
    # POST /v1/generate
    # ------------------------------------------------------------------
    def _parse_generate(self, req: http.Request) -> dict:
        body = req.json()
        model = body.get("model")
        entries = {e.name: e for e in self.session.registry.entries()}
        if len(entries) == 1 and model is None:
            model = next(iter(entries))
        if model not in entries:
            raise http.BadRequest(
                f"unknown model {model!r}; serving "
                f"{sorted(entries)}")
        sla_class = body.get("sla_class", "default")
        if not isinstance(sla_class, str) or not sla_class:
            raise http.BadRequest("sla_class must be a non-empty string")
        deadline = body.get("deadline", self.deadline_by_class.get(
            sla_class))
        if deadline is not None:
            deadline = float(deadline)
            if deadline <= 0:
                raise http.BadRequest("deadline must be positive")
        elif sla_class != "default":
            raise http.BadRequest(
                f"unknown SLA class {sla_class!r} and no deadline given")
        out = {"model": model, "sla_class": sla_class,
               "deadline": deadline,
               "shed_priority": body.get("shed_priority",
                                         entries[model].shed_priority)}
        for field in ("prompt_len", "decode_len"):
            value = body.get(field)
            if value is not None:
                value = int(value)
                if not 0 <= value <= 100_000:
                    raise http.BadRequest(
                        f"{field} out of range: {value}")
            out[field] = value
        if not isinstance(out["shed_priority"], int):
            raise http.BadRequest("shed_priority must be an integer")
        return out

    async def _generate(self, req, reader, writer) -> None:
        rid = request_id()
        loop = asyncio.get_running_loop()
        t_wall = loop.time()
        model = sla_class = "?"
        status = 500
        fate = None
        tokens_sent = 0
        try:
            params = self._parse_generate(req)
        except http.BadRequest as exc:
            await http.send_json(writer, 400, {"error": str(exc)},
                                 extra_headers=[("x-request-id", rid)])
            self._log_http(rid, req, 400, model, sla_class, fate, 0,
                           None, t_wall)
            return
        model, sla_class = params["model"], params["sla_class"]
        if self.draining or not self.ready:
            await http.send_json(writer, 503, {"error": "draining"},
                                 extra_headers=[("x-request-id", rid),
                                                ("retry-after", "1")])
            self._finish_http(rid, req, 503, model, sla_class, "draining",
                              0, None, t_wall)
            return
        hint = self.backpressure.check(model, params["shed_priority"])
        if hint is not None:
            await http.send_json(
                writer, 429,
                {"error": "gateway at capacity", "retry_after": hint},
                extra_headers=[("x-request-id", rid),
                               ("retry-after", f"{hint:.3f}")])
            self._finish_http(rid, req, 429, model, sla_class,
                              "backpressure", 0, None, t_wall)
            return
        try:
            gr = self.driver.submit(
                rid, model, sla_class=sla_class,
                deadline=params["deadline"],
                prompt_len=params["prompt_len"],
                decode_len=params["decode_len"])
        except ValueError as exc:
            await http.send_json(writer, 400, {"error": str(exc)},
                                 extra_headers=[("x-request-id", rid)])
            self._finish_http(rid, req, 400, model, sla_class, None, 0,
                              None, t_wall)
            return
        budget = (TimeoutBudget(loop.time, self.request_timeout)
                  if self.request_timeout is not None else None)
        gone, watcher = http.watch_disconnect(reader)
        sse = http.SSEStream(writer)
        get_task: Optional[asyncio.Task] = None
        gone_task = asyncio.create_task(gone.wait())
        try:
            while True:
                timeout = budget.remaining() if budget else None
                if timeout is not None and timeout <= 0:
                    status, fate = await self._on_timeout(gr, sse, rid)
                    break
                if get_task is None:
                    get_task = asyncio.create_task(gr.events.get())
                done, _ = await asyncio.wait(
                    {get_task, gone_task}, timeout=timeout,
                    return_when=asyncio.FIRST_COMPLETED)
                if not done:                         # timed out
                    status, fate = await self._on_timeout(gr, sse, rid)
                    break
                if gone_task in done and get_task not in done:
                    gr.cancel()
                    status, fate = CLIENT_CLOSED, "client_disconnect"
                    break
                event, payload = get_task.result()
                get_task = None
                if event == EV_TOKEN:
                    if not sse.started:
                        await sse.start([("x-request-id", rid)])
                    await sse.send("token",
                                   {"i": tokens_sent, "token": payload})
                    tokens_sent += 1
                    continue
                if event == EV_END:
                    status, fate = await self._on_end(
                        gr, payload, sse, rid, tokens_sent)
                    break
        except ConnectionError:
            gr.cancel()
            status, fate = CLIENT_CLOSED, "write_failed"
        finally:
            # cancel-and-reap every helper task: an unreaped cancel
            # leaves the task pending past the handler (drain cannot
            # find it) and its exceptions are never observed
            reap = [watcher, gone_task]
            if get_task is not None:
                reap.append(get_task)
            for t in reap:
                t.cancel()
            await asyncio.gather(*reap, return_exceptions=True)
        self._finish_http(rid, req, status, model, sla_class, fate,
                          tokens_sent, gr, t_wall)

    async def _on_timeout(self, gr, sse, rid):
        """Per-request wall-clock budget exhausted: cancel (frees the
        KV slot) and report 408 — in-band if the stream already began."""
        gr.cancel()
        if sse.started:
            await self._try_send(sse, "error",
                                 {"status": 408, "fate": "timeout"})
        else:
            await http.send_json(sse.writer, 408,
                                 {"error": "request timeout"},
                                 extra_headers=[("x-request-id", rid)])
        return 408, "timeout"

    async def _on_end(self, gr, state, sse, rid, tokens_sent):
        fate = state.value
        status = status_for_state(state)
        handle = gr.handle
        summary = {"fate": fate, "tokens": len(handle.tokens),
                   "latency_s": handle.latency, "ttft_s": handle.ttft}
        if status == 200:
            if not sse.started:
                await sse.start([("x-request-id", rid)])
            await self._try_send(sse, "done", summary)
        elif sse.started:                    # status line already sent
            await self._try_send(sse, "error",
                                 {"status": status, **summary})
        else:
            headers = [("x-request-id", rid)]
            if status in RETRYABLE_STATUSES:
                hint = self.backpressure._hint(self.driver.inflight + 1)
                headers.append(("retry-after", f"{hint:.3f}"))
            await http.send_json(sse.writer, status,
                                 {"error": fate, **summary},
                                 extra_headers=headers)
        return status, fate

    async def _try_send(self, sse, event, payload) -> None:
        try:
            await sse.send(event, payload)
        except ConnectionError:
            pass                             # peer left during the final event

    # ------------------------------------------------------------------
    # logging
    # ------------------------------------------------------------------
    def _finish_http(self, rid, req, status, model, sla_class, fate,
                     tokens_sent, gr, t_wall) -> None:
        self.metrics.observe_http(model, sla_class, status,
                                  n_tokens=tokens_sent)
        self._log_http(rid, req, status, model, sla_class, fate,
                       tokens_sent, gr, t_wall)

    def _log_http(self, rid, req, status, model, sla_class, fate,
                  tokens_sent, gr, t_wall) -> None:
        loop = asyncio.get_running_loop()
        fields = {
            "id": rid, "method": req.method, "path": req.path,
            "status": status, "model": model, "sla_class": sla_class,
            "wall_ms": round((loop.time() - t_wall) * 1e3, 3),
            "tokens": tokens_sent,
        }
        if fate is not None:
            fields["fate"] = fate
        if gr is not None and gr.handle.done:
            if gr.handle.latency is not None:
                fields["latency_ms"] = round(gr.handle.latency * 1e3, 3)
            if gr.handle.ttft is not None:
                fields["ttft_ms"] = round(gr.handle.ttft * 1e3, 3)
        self.access_log.emit("http", **fields)
