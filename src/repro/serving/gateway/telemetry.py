"""Gateway observability: the concrete metric set and structured logs.

:class:`GatewayMetrics` owns every metric family the gateway exports
and the three feed points that keep them current:

  * ``on_run_boundary(session, model, done)`` — wired into
    ``ServingSession.on_run_boundary`` by the driver, so the registry is
    fed at every scheduling run boundary (queue depth, arena residency,
    the session's monotone run/fault/retry counters),
  * ``observe_outcome(...)`` — one terminal request outcome (driver
    finalization): per-model/per-class attainment over a rolling
    window, latency/TTFT histograms, rolling TTFT/TPOT means,
  * ``observe_http(...)`` — one completed HTTP exchange (access-log
    moment): request counts by model/class/status, streamed-token and
    backpressure counters.

``sample(session)`` refreshes the point-in-time gauges right before a
``/metrics`` scrape (and adds injected-fault counts when the backend is
a ``FaultInjectingBackend`` — duck-typed via ``fault_stats`` so the
gateway works over any backend stack).

:class:`AccessLog` writes one JSON object per line (machine-parseable,
one event per HTTP exchange plus lifecycle events like ``ready`` /
``drain``); ``request_id()`` tags each exchange with a process-unique
id that appears in the access log and the ``X-Request-Id`` response
header.
"""
from __future__ import annotations

import itertools
import json
import os
import sys
from typing import Dict, List, Optional

from .prom import DEFAULT_BUCKETS, MetricsRegistry

_req_seq = itertools.count(1)
_RID_PREFIX = f"{os.getpid():08x}"


def request_id() -> str:
    """Process-unique request id: pid-prefixed monotone counter (cheap,
    collision-free within one gateway, and greppable across its logs)."""
    return f"{_RID_PREFIX}-{next(_req_seq):08x}"


class AccessLog:
    """Structured JSON-lines log. Each record is one event object; the
    gateway emits ``http`` records per exchange (request id, method,
    path, status, model, class, fate, token/latency figures) and
    lifecycle records (``ready``, ``metrics``, ``drain``)."""

    def __init__(self, stream=None, enabled: bool = True):
        self.stream = stream if stream is not None else sys.stderr
        self.enabled = enabled
        self.records: List[dict] = []       # in-memory tail for tests
        self.keep = 1000

    def emit(self, event: str, **fields):
        record = {"event": event, **fields}
        self.records.append(record)
        if len(self.records) > self.keep:
            del self.records[:len(self.records) - self.keep]
        if self.enabled:
            print(json.dumps(record, sort_keys=True), file=self.stream,
                  flush=True)
        return record


class GatewayMetrics:
    """Every metric family the gateway exposes, with typed feed points.

    Durations are in seconds on the session clock; ``deadline_by_class``
    maps SLA class name -> relative deadline for attainment judging
    (``default_sla`` covers the default class).
    """

    def __init__(self, *, default_sla: Optional[float] = None,
                 deadline_by_class: Optional[Dict[str, float]] = None,
                 window: int = 256,
                 buckets=DEFAULT_BUCKETS):
        self.default_sla = default_sla
        self.deadlines = dict(deadline_by_class or {})
        reg = self.registry = MetricsRegistry()
        self.requests = reg.counter(
            "gateway_requests_total",
            "completed HTTP exchanges by model, SLA class and status",
            ("model", "sla_class", "status"))
        self.backpressure = reg.counter(
            "gateway_backpressure_total",
            "requests refused with 429 at the bounded ingress",
            ("model",))
        self.tokens = reg.counter(
            "gateway_tokens_streamed_total",
            "SSE tokens streamed to clients", ("model",))
        self.outcomes = reg.counter(
            "gateway_outcomes_total",
            "terminal request fates as seen by the session",
            ("model", "fate"))
        self.latency = reg.histogram(
            "gateway_request_latency_seconds",
            "arrival-to-completion latency (session clock)",
            ("model",), buckets)
        self.ttft = reg.histogram(
            "gateway_ttft_seconds",
            "arrival-to-first-token latency (session clock)",
            ("model",), buckets)
        self.attainment = reg.rolling(
            "gateway_attainment",
            "rolling SLA attainment over recent terminal outcomes",
            ("model", "sla_class"), window)
        self.rolling_ttft = reg.rolling(
            "gateway_ttft_seconds_rolling",
            "rolling mean TTFT over recent completions (session clock)",
            ("model",), window)
        self.rolling_tpot = reg.rolling(
            "gateway_tpot_seconds_rolling",
            "rolling mean time-per-output-token over recent completions",
            ("model",), window)
        self.queue_depth = reg.gauge(
            "gateway_queue_depth",
            "requests waiting in the model policy's admission queue",
            ("model",))
        self.inflight = reg.gauge(
            "gateway_inflight",
            "live gateway requests (submitted, not yet terminal)")
        self.slots_live = reg.gauge(
            "gateway_arena_slots_live", "resident KV slots (pool-wide)")
        self.slots_total = reg.gauge(
            "gateway_arena_slots_total", "current KV pool capacity")
        self.slots_max = reg.gauge(
            "gateway_arena_slots_max",
            "configured KV pool hard cap (NaN = unbounded)")
        self.bytes_resident = reg.gauge(
            "gateway_arena_bytes_resident", "resident KV bytes (pool-wide)")
        self.runs = reg.counter(
            "gateway_session_runs_total", "committed runs executed")
        self.faults = reg.counter(
            "gateway_session_faults_total",
            "backend faults the session absorbed")
        self.retries = reg.counter(
            "gateway_session_retries_total", "fault-retry requeue events")
        self.injected = reg.counter(
            "gateway_injected_faults_total",
            "faults injected by the chaos backend",
            ("model", "kind"))
        self.loop_max_stall = reg.gauge(
            "gateway_loop_max_stall_seconds",
            "worst event-loop callback latency the stall watchdog saw")
        self.loop_lag_p99 = reg.gauge(
            "gateway_loop_lag_p99_seconds",
            "p99 event-loop wakeup lag over the watchdog's recent window")
        self.loop_stalls = reg.counter(
            "gateway_loop_stalls_total",
            "watchdog probes whose lag exceeded the stall threshold")
        self.loop_ticks = reg.counter(
            "gateway_loop_ticks_total", "stall-watchdog probes taken")

    # ------------------------------------------------------------------
    def deadline_for(self, sla_class: str) -> Optional[float]:
        if sla_class in self.deadlines:
            return self.deadlines[sla_class]
        return self.default_sla

    # ------------------------------------------------------------------
    # feed points
    # ------------------------------------------------------------------
    def on_run_boundary(self, session, model: str, done) -> None:
        """Session hook: refresh the session-derived series at a run
        boundary. ``done`` (the requests finished by this run) is unused
        here — terminal accounting runs through the driver's
        finalization, which also sees cancel/expiry/shed fates."""
        self.sample_session(session)

    def sample_session(self, session) -> None:
        for entry in session.registry.entries():
            self.queue_depth.set(len(entry.policy.queue), model=entry.name)
        mem = session.backend.memory_stats()
        self.slots_live.set(mem.slots_live)
        self.slots_total.set(mem.slots_total)
        self.slots_max.set(mem.max_slots if mem.max_slots is not None
                           else float("nan"))
        self.bytes_resident.set(mem.bytes_resident)
        self.runs.set_total(session.log.runs_executed)
        self.faults.set_total(session.log.faults)
        self.retries.set_total(session.retried)
        fault_stats = getattr(session.backend, "fault_stats", None)
        if callable(fault_stats):
            for model, kinds in fault_stats().items():
                for kind, n in kinds.items():
                    self.injected.set_total(n, model=model, kind=kind)

    def sample_loop(self, sanitizer) -> None:
        """Mirror the loop-stall watchdog's counters into the registry
        (scrape-time refresh, same idiom as ``sample_session``)."""
        if sanitizer is None:
            return
        stats = sanitizer.stats
        self.loop_max_stall.set(stats.max_lag_s)
        self.loop_lag_p99.set(stats.lag_p99_s())
        self.loop_stalls.set_total(stats.stalls)
        self.loop_ticks.set_total(stats.ticks)

    def observe_outcome(self, model: str, sla_class: str, fate: str,
                        latency_s: Optional[float],
                        ttft_s: Optional[float],
                        n_tokens: int) -> None:
        """One terminal request outcome (driver finalization)."""
        self.outcomes.inc(model=model, fate=fate)
        deadline = self.deadline_for(sla_class)
        if deadline is not None:
            ok = (fate == "done" and latency_s is not None
                  and latency_s <= deadline)
            self.attainment.observe(1.0 if ok else 0.0,
                                    model=model, sla_class=sla_class)
        if latency_s is not None:
            self.latency.observe(latency_s, model=model)
        if ttft_s is not None:
            self.ttft.observe(ttft_s, model=model)
            self.rolling_ttft.observe(ttft_s, model=model)
            if latency_s is not None and n_tokens >= 2:
                self.rolling_tpot.observe(
                    (latency_s - ttft_s) / (n_tokens - 1), model=model)

    def observe_http(self, model: str, sla_class: str, status: int,
                     n_tokens: int = 0) -> None:
        """One completed HTTP exchange (access-log moment)."""
        self.requests.inc(model=model, sla_class=sla_class,
                          status=str(status))
        if status == 429:
            self.backpressure.inc(model=model)
        if n_tokens:
            self.tokens.inc(n_tokens, model=model)

    # ------------------------------------------------------------------
    def expose(self) -> str:
        return self.registry.expose()

    def snapshot(self) -> dict:
        """Compact dict for the periodic metrics log line."""
        att = {}
        for key, dq in self.attainment._series.items():
            if dq:
                att["/".join(key)] = round(sum(dq) / len(dq), 4)
        return {
            "inflight": self.inflight.value(),
            "slots_live": self.slots_live.value(),
            "slots_total": self.slots_total.value(),
            "runs": self.runs.total(),
            "faults": self.faults.total(),
            "retries": self.retries.total(),
            "requests": self.requests.total(),
            "backpressure_429": self.backpressure.total(),
            "tokens_streamed": self.tokens.total(),
            "loop_stalls": self.loop_stalls.total(),
            "loop_max_stall_s": self.loop_max_stall.value(),
            "attainment": att,
        }
