"""Minimal HTTP/1.1 + SSE plumbing over stdlib asyncio streams.

Deliberately small: the gateway serves ``Connection: close`` exchanges
(one request per TCP connection) which keeps the parser to a request
line, a header block, and an optional ``Content-Length`` body — no
keep-alive state machine, no chunked *request* bodies, no TLS. SSE
responses are written straight to the stream with explicit ``drain()``
per event so a slow client exerts backpressure on its own stream only.

Client disconnects are detected two ways (both matter in practice):

  * a **reader watcher** task awaits EOF on the request's read side —
    a client that aborts mid-SSE closes its socket, which surfaces as
    EOF long before the next write would fail, and
  * **write failures** — ``ConnectionError`` from ``drain()`` when the
    peer reset.

Either path sets the returned ``gone`` event; the request handler
treats it as a cancellation signal (``handle.cancel()`` → slot freed).
"""
from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional, Sequence, Tuple

from .middleware import STATUS_REASONS

#: Upper bounds keeping a misbehaving client from ballooning memory.
MAX_HEADER_BYTES = 16384
MAX_BODY_BYTES = 1 << 20


class BadRequest(Exception):
    """Malformed HTTP from the client (maps to a 400 response)."""


class Request:
    """One parsed HTTP request."""

    def __init__(self, method: str, path: str, headers: Dict[str, str],
                 body: bytes):
        self.method = method
        self.path = path
        self.headers = headers
        self.body = body

    def json(self) -> dict:
        if not self.body:
            return {}
        try:
            payload = json.loads(self.body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as exc:
            raise BadRequest(f"invalid JSON body: {exc}") from exc
        if not isinstance(payload, dict):
            raise BadRequest("JSON body must be an object")
        return payload


async def read_request(reader: asyncio.StreamReader) -> Optional[Request]:
    """Parse one request off the stream; None on EOF before any bytes."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as exc:
        if not exc.partial:
            return None
        raise BadRequest("truncated request head") from exc
    except asyncio.LimitOverrunError as exc:
        raise BadRequest("request head too large") from exc
    if len(head) > MAX_HEADER_BYTES:
        raise BadRequest("request head too large")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise BadRequest(f"malformed request line: {lines[0]!r}")
    method, target = parts[0].upper(), parts[1]
    headers: Dict[str, str] = {}
    for line in lines[1:]:
        if not line:
            continue
        name, sep, value = line.partition(":")
        if not sep:
            raise BadRequest(f"malformed header line: {line!r}")
        headers[name.strip().lower()] = value.strip()
    body = b""
    if "content-length" in headers:
        try:
            length = int(headers["content-length"])
        except ValueError as exc:
            raise BadRequest("bad Content-Length") from exc
        if length < 0 or length > MAX_BODY_BYTES:
            raise BadRequest(f"unacceptable Content-Length {length}")
        if length:
            try:
                body = await reader.readexactly(length)
            except asyncio.IncompleteReadError as exc:
                raise BadRequest("truncated request body") from exc
    path = target.split("?", 1)[0]
    return Request(method, path, headers, body)


def _head(status: int,
          headers: Sequence[Tuple[str, str]] = ()) -> bytes:
    reason = STATUS_REASONS.get(status, "Unknown")
    lines = [f"HTTP/1.1 {status} {reason}"]
    lines += [f"{name}: {value}" for name, value in headers]
    lines.append("connection: close")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")


async def send_response(writer: asyncio.StreamWriter, status: int,
                        body: bytes = b"",
                        content_type: str = "application/json",
                        extra_headers: Sequence[Tuple[str, str]] = ()
                        ) -> None:
    headers = [("content-type", content_type),
               ("content-length", str(len(body)))]
    headers += list(extra_headers)
    writer.write(_head(status, headers) + body)
    await writer.drain()


async def send_json(writer: asyncio.StreamWriter, status: int,
                    payload: dict,
                    extra_headers: Sequence[Tuple[str, str]] = ()) -> None:
    body = (json.dumps(payload, sort_keys=True) + "\n").encode("utf-8")
    await send_response(writer, status, body,
                        extra_headers=extra_headers)


class SSEStream:
    """Server-Sent Events writer over a raw StreamWriter. Events carry a
    JSON payload; the terminal event is ``done`` (success) or ``error``
    (a non-200 fate after streaming already started — the HTTP status
    was committed at 200, so the fate rides in-band)."""

    def __init__(self, writer: asyncio.StreamWriter):
        self.writer = writer
        self.started = False
        self.events_sent = 0

    async def start(self, extra_headers: Sequence[Tuple[str, str]] = ()
                    ) -> None:
        headers = [("content-type", "text/event-stream"),
                   ("cache-control", "no-store")]
        headers += list(extra_headers)
        self.writer.write(_head(200, headers))
        await self.writer.drain()
        self.started = True

    async def send(self, event: str, payload: dict) -> None:
        data = json.dumps(payload, sort_keys=True)
        self.writer.write(f"event: {event}\ndata: {data}\n\n"
                          .encode("utf-8"))
        await self.writer.drain()
        self.events_sent += 1


def watch_disconnect(reader: asyncio.StreamReader
                     ) -> Tuple[asyncio.Event, asyncio.Task]:
    """Start a task that sets an event when the peer closes its write
    side (EOF on our reader). Callers must cancel the task when the
    exchange ends normally."""
    gone = asyncio.Event()

    async def _watch():
        try:
            while True:
                chunk = await reader.read(4096)
                if not chunk:
                    break
        except asyncio.CancelledError:
            raise                        # exchange ended normally
        except ConnectionError:
            pass                         # peer reset == peer gone
        gone.set()

    task = asyncio.create_task(_watch())
    return gone, task
