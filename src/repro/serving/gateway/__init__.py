"""Asyncio serving gateway: HTTP/SSE ingress over ServingSession.

The gateway is the repo's network front-end (ROADMAP: "a network
front-end with backpressure and live observability"): an asyncio HTTP
server streaming tokens over SSE, a bounded-ingress middleware stack,
a Prometheus-style metrics registry fed at run boundaries, and the
audited wall-clock <-> session-clock bridge that lets the same server
run over the virtual-time sim backend (paced by ``time_scale``) or the
JAX engine (real run latencies).

Kept as an explicit subpackage import (``repro.serving.gateway``) so
importing ``repro.serving`` alone stays asyncio-free.
"""
from .app import GatewayApp
from .bridge import GatewayRequest, SessionDriver
from .middleware import (FATE_STATUS, Backpressure, TimeoutBudget,
                         status_for_state)
from .prom import (Counter, Gauge, Histogram, MetricsRegistry, Rolling,
                   DEFAULT_BUCKETS)
from .sanitizer import LoopStallSanitizer, LoopStallStats
from .telemetry import AccessLog, GatewayMetrics, request_id

__all__ = [
    "GatewayApp", "GatewayRequest", "SessionDriver",
    "FATE_STATUS", "Backpressure", "TimeoutBudget", "status_for_state",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Rolling",
    "DEFAULT_BUCKETS", "AccessLog", "GatewayMetrics", "request_id",
    "LoopStallSanitizer", "LoopStallStats",
]
