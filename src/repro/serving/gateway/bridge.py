"""SessionDriver: the audited wall-clock <-> session-clock bridge.

This module is the ONE place where wall time meets the serving core's
session clock, and it is declared an audited wall-clock boundary in the
reprolint scope config (``repro.analysis.base.WALLCLOCK_AUDITED_PREFIXES``
covers ``repro/serving/gateway/``): pacing SSE streams, Retry-After
hints, and request timeouts are inherently wall-clock concerns, while
everything at or below :class:`~repro.serving.session.ServingSession`
stays on the virtual/event clock. The bridge rule:

  * **wall -> session, one direction, one mapping.** The driver anchors
    the event-loop clock at :meth:`start` and maps elapsed wall time to
    a session-clock *target*: ``target = (loop.time() - t0) *
    time_scale``. Each pump tick calls ``session.run_until(target)`` —
    the scheduler executes every run that starts at or before the
    target and the session clock never runs ahead of the mapping (sim
    runs are instantaneous in wall time). Under the JAX engine the
    session clock is itself wall-measured run latency, so the same loop
    simply keeps idle time honest between dispatches.
  * **session values never flow back into wall-clock arithmetic** except
    for display/logging — deadlines, latencies, and attainment are all
    judged on the session clock exactly as in offline replay, so a
    gateway run at ``time_scale=50`` reports the same SLA numbers the
    simulator would.

``time_scale`` compresses wall time for the sim backend (50x means one
wall second carries 50 virtual seconds of traffic — tests and CI smokes
use this); the JAX engine should run at 1.0 (its run latencies are real
seconds already).
"""
from __future__ import annotations

import asyncio
import zlib
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from ...core.request import Request, SLAClass
from ..session import RequestHandle, ServingSession

#: Stream-event kinds put on a GatewayRequest's queue.
EV_TOKEN = "token"
EV_END = "end"


class GatewayRequest:
    """One in-flight gateway exchange: the session handle plus the
    asyncio queue its HTTP handler consumes stream events from."""

    def __init__(self, request_id: str, model: str, sla_class: str,
                 handle: RequestHandle):
        self.request_id = request_id
        self.model = model
        self.sla_class = sla_class
        self.handle = handle
        self.events: asyncio.Queue = asyncio.Queue()

    @property
    def rid(self) -> int:
        return self.handle.request.rid

    def cancel(self) -> bool:
        return self.handle.cancel()


class SessionDriver:
    """Owns the ServingSession inside the gateway's event loop: paces
    the session clock against the wall, submits HTTP-originated
    requests, streams their tokens out, and finalizes terminal handles.

    Single-threaded by construction — every method runs on the event
    loop thread, interleaved with the HTTP handlers, so no locking is
    needed around session state (the session is not thread-safe and
    never needs to be here).
    """

    def __init__(self, session: ServingSession, *,
                 time_scale: float = 1.0, tick: float = 0.002,
                 metrics=None, access_log=None,
                 metrics_log_interval: Optional[float] = None,
                 seed: int = 0, rate_window: float = 5.0):
        if time_scale <= 0 or tick <= 0:
            raise ValueError(
                f"time_scale and tick must be positive "
                f"(got {time_scale}, {tick})")
        self.session = session
        self.time_scale = time_scale
        self.tick = tick
        self.metrics = metrics
        self.access_log = access_log
        self.metrics_log_interval = metrics_log_interval
        self.seed = seed
        self.rate_window = rate_window
        # single-writer fields: only the pump task's synchronous
        # advance/finalize path mutates these (handlers read them via
        # the admission views) — declared so await-atomicity spans on
        # them are sanctioned file-wide
        self.active: Dict[int, GatewayRequest] = {}  # reprolint: owner=pump
        self.completed = 0                   # reprolint: owner=pump
        self._t0: Optional[float] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stopping = False               # reprolint: owner=pump
        self._done_stamps: deque = deque()   # reprolint: owner=pump
        self._length_rngs: Dict[str, np.random.Generator] = {}
        self._sla_classes: Dict[str, SLAClass] = {}
        self._last_metrics_log = 0.0         # reprolint: owner=pump

    # ------------------------------------------------------------------
    # clock mapping
    # ------------------------------------------------------------------
    def start(self) -> None:
        """Anchor the wall clock and wire the session's run-boundary
        feed. Must be called from inside the running event loop."""
        self._loop = asyncio.get_running_loop()
        self._t0 = self._loop.time()
        self._last_metrics_log = self._t0
        if self.metrics is not None:
            self.session.on_run_boundary = self.metrics.on_run_boundary

    def wall(self) -> float:
        if self._loop is None:
            raise RuntimeError("SessionDriver.start() was never called")
        return self._loop.time()

    def target(self) -> float:
        """Session-clock target for the current wall instant."""
        return (self.wall() - self._t0) * self.time_scale

    # ------------------------------------------------------------------
    # pump
    # ------------------------------------------------------------------
    def advance(self) -> None:
        """Advance the session to the current wall-mapped target and
        finalize any handles that went terminal."""
        # AUDITED loop-blocking seed: the pump tick's catch-up is the
        # one sanctioned place scheduler work runs on the event loop —
        # bounded by the tick budget (per-tick targets advance by
        # tick * time_scale), and the stall watchdog enforces the
        # budget at runtime. Every transitive caller (pump, submit's
        # mini-tick, GatewayApp.drain) is sanctioned through this seed.
        self.session.run_until(self.target())  # reprolint: disable=blocking-in-async
        self._finalize()
        if self.metrics is not None:
            self.metrics.inflight.set(len(self.active))

    async def pump(self) -> None:
        """Background pacing task: advance every ``tick`` wall seconds
        until :meth:`stop`; emits the periodic metrics log line."""
        while not self._stopping:
            self.advance()
            self._maybe_log_metrics()
            await asyncio.sleep(self.tick)

    def stop(self) -> None:
        self._stopping = True

    def _maybe_log_metrics(self) -> None:
        if (self.metrics_log_interval is None or self.metrics is None
                or self.access_log is None):
            return
        now = self.wall()
        if now - self._last_metrics_log >= self.metrics_log_interval:
            self._last_metrics_log = now
            self.metrics.sample_session(self.session)
            self.access_log.emit("metrics", **self.metrics.snapshot())

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def sla_class_for(self, name: str,
                      deadline: Optional[float]) -> Optional[SLAClass]:
        """Session SLAClass for a tier name (memoized so every request
        of a tier shares one instance). ``default`` with no explicit
        deadline means "no per-request class" — the policy predictor's
        global target applies."""
        if name == "default" and deadline is None:
            return None
        if deadline is None:
            raise ValueError(f"SLA class {name!r} has no deadline")
        cls = self._sla_classes.get(name)
        if cls is None:
            cls = SLAClass(name=name, deadline=deadline)
            self._sla_classes[name] = cls
        return cls

    def _length_rng(self, model: str) -> np.random.Generator:
        rng = self._length_rngs.get(model)
        if rng is None:
            # per-model stream, independent of cross-model interleaving
            rng = np.random.default_rng(
                [self.seed, zlib.crc32(model.encode()), 0x1E46])
            self._length_rngs[model] = rng
        return rng

    def submit(self, request_id: str, model: str, *,
               sla_class: str = "default",
               deadline: Optional[float] = None,
               prompt_len: Optional[int] = None,
               decode_len: Optional[int] = None) -> GatewayRequest:
        """Build a Request for ``model``'s workload and submit it at the
        current session-clock instant. Unspecified lengths are sampled
        from the workload's own distributions (per-model seeded
        streams, so one tenant's traffic never perturbs another's)."""
        entry = self.session.registry[model]
        wl = entry.workload
        if wl is None:
            raise ValueError(
                f"model {model!r} was registered without a workload — "
                f"the gateway cannot build request sequences for it")
        rng = self._length_rng(model)
        p = (int(prompt_len) if prompt_len is not None
             else (wl.prompt_dist.sample(rng) if wl.prompt_dist else 0))
        d = (int(decode_len) if decode_len is not None
             else (wl.decode_dist.sample(rng) if wl.decode_dist else 0))
        seq, prefix_len, cycle_len = wl.build_sequence(p, d)
        if not seq:
            raise ValueError(
                f"empty request sequence for model {model!r} "
                f"(prompt_len={p}, decode_len={d})")
        self.advance()                       # session clock == wall target
        req = Request(workload=wl, arrival=self.session.now, sequence=seq,
                      sla=self.sla_class_for(sla_class, deadline))
        req.prompt_len = p
        req.decode_len = d
        req.prefix_len = prefix_len
        req.cycle_len = cycle_len
        gr_box: List[GatewayRequest] = []

        def _on_token(handle, token):
            gr_box[0].events.put_nowait((EV_TOKEN, token))

        handle = self.session.submit(req, model=model, on_token=_on_token)
        gr = GatewayRequest(request_id, model, sla_class, handle)
        gr_box.append(gr)
        if handle.done:                      # REJECTED at admission
            self._finish(gr)
        else:
            self.active[req.rid] = gr
        return gr

    # ------------------------------------------------------------------
    # finalization
    # ------------------------------------------------------------------
    def _finalize(self) -> None:
        done = [gr for gr in self.active.values() if gr.handle.done]
        for gr in done:
            del self.active[gr.rid]
            self._finish(gr)

    def _finish(self, gr: GatewayRequest) -> None:
        handle = gr.handle
        fate = handle.state.value
        if fate == "done":
            self.completed += 1
            self._done_stamps.append(self.wall())
        if self.metrics is not None:
            self.metrics.observe_outcome(
                gr.model, gr.sla_class, fate,
                latency_s=handle.latency, ttft_s=handle.ttft,
                n_tokens=len(handle.tokens))
        gr.events.put_nowait((EV_END, handle.state))

    # ------------------------------------------------------------------
    # admission-support views (used by the Backpressure middleware)
    # ------------------------------------------------------------------
    @property
    def inflight(self) -> int:
        return len(self.active)

    def protected_priority(self) -> int:
        return self.session._protected_priority()

    def mem_room(self, model: str) -> Optional[int]:
        """Free-slot admission room for ``model`` under memory-aware
        admission (None = unbounded pool)."""
        if not self.session.memory_aware:
            return None
        return self.session._mem_room(self.session.registry[model])

    def completion_rate(self) -> float:
        """Completions per wall second over the trailing window."""
        if self._loop is None:
            return 0.0
        now = self.wall()
        while self._done_stamps and self._done_stamps[0] < now - self.rate_window:
            self._done_stamps.popleft()
        if not self._done_stamps:
            return 0.0
        return len(self._done_stamps) / self.rate_window

    # ------------------------------------------------------------------
    # shutdown
    # ------------------------------------------------------------------
    def drain(self):
        """Run everything outstanding to completion (virtual fast-forward
        — pacing no longer applies during shutdown) and finalize every
        remaining handle. Returns the drained ServeStats."""
        self.stop()
        # AUDITED loop-blocking seed: shutdown fast-forward — pacing
        # (and loop liveness for new work) no longer applies; the
        # server socket is already closed when GatewayApp calls this.
        stats = self.session.drain()  # reprolint: disable=blocking-in-async
        self._finalize()
        if self.metrics is not None:
            self.metrics.sample_session(self.session)
            self.metrics.inflight.set(len(self.active))
        return stats
