"""Prometheus-style metrics registry for the serving gateway.

Self-contained (stdlib-only) implementation of the three metric
families the gateway needs, plus a rolling-window ratio/mean type for
SLA attainment over recent outcomes:

  * :class:`Counter`   — monotone totals (``gateway_requests_total``);
    ``inc()`` for event feeds, ``set_total()`` for sampling an already-
    monotone upstream counter (the session's ``runs_executed``) without
    double counting,
  * :class:`Gauge`     — point-in-time values (queue depth, arena
    residency), re-sampled at scrape time,
  * :class:`Histogram` — cumulative-bucket distributions with
    configurable upper bounds (request latency, TTFT), exposed with the
    standard ``_bucket{le=...}`` / ``_sum`` / ``_count`` series,
  * :class:`Rolling`   — a fixed-window deque of recent observations
    exposed as a gauge (mean over the window). ``Rolling`` of 0/1
    outcomes is the gateway's *live* per-model/per-class attainment:
    unlike a since-boot ratio it recovers when an overload clears,
    which is what an operator (or the brownout controller) wants to
    watch.

Exposition follows the Prometheus text format (version 0.0.4): one
``# HELP`` / ``# TYPE`` pair per family, label values escaped, series
in insertion order. All durations are exported in **seconds** on the
session clock (the SLA-relevant clock — virtual under the sim backend,
wall under the JAX engine); metric names carry the ``gateway_`` prefix
and counters end in ``_total`` (see README "Serving gateway" for the
full naming convention).
"""
from __future__ import annotations

import math
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_LabelKey = Tuple[str, ...]


def _escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(value: float) -> str:
    if isinstance(value, float):
        if math.isnan(value):
            return "NaN"
        if math.isinf(value):
            return "+Inf" if value > 0 else "-Inf"
        if value == int(value) and abs(value) < 1e15:
            return str(int(value))
    return repr(value) if isinstance(value, float) else str(value)


class Metric:
    """One metric family: a name, help text, declared label names, and
    a per-label-value-tuple series table."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: Dict[_LabelKey, object] = {}

    def _key(self, labels: Dict[str, str]) -> _LabelKey:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def _render_labels(self, key: _LabelKey,
                       extra: Sequence[Tuple[str, str]] = ()) -> str:
        pairs = [f'{n}="{_escape(v)}"'
                 for n, v in zip(self.labelnames, key)]
        pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""

    def samples(self) -> Iterable[Tuple[str, str, float]]:
        """Yield ``(suffix, rendered_labels, value)`` rows."""
        raise NotImplementedError

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for suffix, labels, value in self.samples():
            lines.append(f"{self.name}{suffix}{labels} {_fmt(value)}")
        return lines


class Counter(Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels):
        if amount < 0:
            raise ValueError(
                f"counter {self.name} cannot decrease (inc {amount})")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def set_total(self, value: float, **labels):
        """Feed an upstream *already-monotone* counter by absolute value
        (e.g. the session's ``runs_executed`` sampled at run
        boundaries): the series takes ``max(current, value)`` so
        re-sampling is idempotent and monotonicity is preserved."""
        key = self._key(labels)
        self._series[key] = max(self._series.get(key, 0.0), float(value))

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))

    def total(self) -> float:
        return float(sum(self._series.values()))

    def samples(self):
        for key, value in self._series.items():
            yield "", self._render_labels(key), value


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: float, **labels):
        self._series[self._key(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), float("nan")))

    def samples(self):
        for key, value in self._series.items():
            yield "", self._render_labels(key), value


#: Default latency buckets (seconds, session clock): spans the sim
#: workloads' ms-scale SLAs and the JAX engine's CPU wall-clock runs.
DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
                   2.5, 5.0, 10.0)


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds or any(b <= 0 for b in bounds):
            raise ValueError(
                f"histogram {name} needs positive, non-empty buckets, "
                f"got {buckets}")
        self.bounds = bounds

    def observe(self, value: float, **labels):
        key = self._key(labels)
        row = self._series.get(key)
        if row is None:
            row = {"buckets": [0] * len(self.bounds),
                   "sum": 0.0, "count": 0}
            self._series[key] = row
        for i, bound in enumerate(self.bounds):
            if value <= bound:
                row["buckets"][i] += 1
        row["sum"] += float(value)
        row["count"] += 1

    def count(self, **labels) -> int:
        row = self._series.get(self._key(labels))
        return 0 if row is None else row["count"]

    def samples(self):
        for key, row in self._series.items():
            for bound, n in zip(self.bounds, row["buckets"]):
                yield ("_bucket",
                       self._render_labels(key, [("le", _fmt(bound))]), n)
            yield ("_bucket",
                   self._render_labels(key, [("le", "+Inf")]),
                   row["count"])
            yield "_sum", self._render_labels(key), row["sum"]
            yield "_count", self._render_labels(key), row["count"]


class Rolling(Metric):
    """Rolling-window mean exposed as a gauge: each series keeps its last
    ``window`` observations; the exported value is their mean (NaN until
    the first observation). Observing 0/1 outcomes makes this a live
    attainment ratio; observing durations makes it a rolling mean."""

    kind = "gauge"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (), window: int = 256):
        super().__init__(name, help, labelnames)
        if window < 1:
            raise ValueError(f"rolling window must be >= 1, got {window}")
        self.window = window

    def observe(self, value: float, **labels):
        key = self._key(labels)
        dq = self._series.get(key)
        if dq is None:
            dq = deque(maxlen=self.window)
            self._series[key] = dq
        dq.append(float(value))

    def value(self, **labels) -> float:
        dq = self._series.get(self._key(labels))
        if not dq:
            return float("nan")
        return sum(dq) / len(dq)

    def samples(self):
        for key, dq in self._series.items():
            mean = sum(dq) / len(dq) if dq else float("nan")
            yield "", self._render_labels(key), mean


class MetricsRegistry:
    """Name-keyed collection of metric families with one text-format
    exposition entry point (the body of ``GET /metrics``)."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def register(self, metric: Metric) -> Metric:
        cur = self._metrics.get(metric.name)
        if cur is not None:
            raise ValueError(f"metric {metric.name!r} already registered")
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name, help, labelnames=()) -> Counter:
        return self.register(Counter(name, help, labelnames))

    def gauge(self, name, help, labelnames=()) -> Gauge:
        return self.register(Gauge(name, help, labelnames))

    def histogram(self, name, help, labelnames=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self.register(Histogram(name, help, labelnames, buckets))

    def rolling(self, name, help, labelnames=(),
                window: int = 256) -> Rolling:
        return self.register(Rolling(name, help, labelnames, window))

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def expose(self) -> str:
        lines: List[str] = []
        for metric in self._metrics.values():
            lines.extend(metric.expose())
        return "\n".join(lines) + "\n"
