"""Event-loop stall watchdog: the runtime mirror of blocking-in-async.

The static checker proves no *known* blocking primitive is reachable
from the gateway's async surface; this sanitizer catches everything the
checker cannot see — a slow C extension, an accidental O(n^2) pass over
the backlog, a pump tick whose sanctioned ``run_until`` catch-up grows
past its budget. The technique is the classic asyncio watchdog: a task
that sleeps a short ``interval`` and measures how late the loop woke it
up. Overshoot beyond the interval is *callback latency* — some callback
(ours or a peer task's) held the loop that long — so the maximum
overshoot bounds the worst stall any concurrently-running handler
observed.

Counters follow the ``SanitizerStats`` idiom from the JAX engine
(cheap monotone counts, scraped not pushed): ``ticks`` probes taken,
``stalls`` probes whose lag exceeded ``threshold``, ``max_lag_s`` the
worst observed lag, and a bounded recent-lag window for the p99 gauge.
``GatewayMetrics.sample_loop`` mirrors them into ``/metrics`` at scrape
time and the gateway CI smoke asserts ``stalls == 0`` under load
(``--assert-no-stall``).
"""
from __future__ import annotations

import asyncio
from collections import deque
from typing import Optional


class LoopStallStats:
    """Monotone stall counters plus a bounded recent-lag window."""

    __slots__ = ("ticks", "stalls", "max_lag_s", "recent")

    def __init__(self, window: int = 2048):
        self.ticks = 0
        self.stalls = 0
        self.max_lag_s = 0.0
        self.recent: deque = deque(maxlen=window)

    def observe(self, lag_s: float, threshold_s: float) -> None:
        self.ticks += 1
        self.recent.append(lag_s)
        if lag_s > self.max_lag_s:
            self.max_lag_s = lag_s
        if lag_s > threshold_s:
            self.stalls += 1

    def lag_p99_s(self) -> float:
        if not self.recent:
            return 0.0
        ordered = sorted(self.recent)
        return ordered[min(len(ordered) - 1,
                           int(0.99 * (len(ordered) - 1) + 0.5))]

    def as_dict(self) -> dict:
        return {"ticks": self.ticks, "stalls": self.stalls,
                "max_lag_s": round(self.max_lag_s, 6),
                "lag_p99_s": round(self.lag_p99_s(), 6)}


class LoopStallSanitizer:
    """Watchdog task measuring event-loop callback latency.

    ``interval`` is the probe period (wall seconds — small enough to
    catch stalls between pump ticks, large enough to cost nothing);
    ``threshold`` is the lag above which a probe counts as a *stall*.
    The defaults (5 ms probe, 250 ms threshold) flag anything that
    would visibly freeze concurrent SSE streams while ignoring
    scheduler jitter under load.
    """

    def __init__(self, *, interval: float = 0.005,
                 threshold: float = 0.25, window: int = 2048):
        if interval <= 0 or threshold <= 0:
            raise ValueError(
                f"interval and threshold must be positive "
                f"(got {interval}, {threshold})")
        self.interval = interval
        self.threshold = threshold
        self.stats = LoopStallStats(window)
        self._task: Optional[asyncio.Task] = None
        self._stopping = False

    # ------------------------------------------------------------------
    async def _watch(self) -> None:
        loop = asyncio.get_running_loop()
        while not self._stopping:
            before = loop.time()
            await asyncio.sleep(self.interval)
            lag = loop.time() - before - self.interval
            self.stats.observe(max(0.0, lag), self.threshold)

    def start(self) -> None:
        """Spawn the watchdog on the running loop (idempotent)."""
        if self._task is None or self._task.done():
            self._stopping = False
            self._task = asyncio.create_task(self._watch())

    async def stop(self) -> None:
        """Cancel the watchdog and reap it."""
        self._stopping = True
        task, self._task = self._task, None
        if task is not None:
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass                         # reaping our own cancel
