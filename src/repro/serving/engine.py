"""Real-JAX node-level serving engine with a persistent KV-cache slot arena.

The discrete-event simulator (``server.py``) models latency analytically;
this engine executes the SAME policies against the ACTUAL model: every
``(sub_batch, node_id)`` the scheduler emits dispatches a jitted per-layer
function on device and mutates real request state (activations, KV caches,
generated tokens). It is the existence proof of the paper's claim that
node-level preemption needs no hardware support — preemption is just
"which jitted node fn we dispatch next" (DESIGN.md §3).

Node ids come from ``workload.from_model_config`` (each ``NodeDesc``
carries ``phase``/``layer`` metadata the dispatcher keys on):

  * ``emb``   — embed the prompt,
  * ``P<i>``  — prefill layer i over the prompt (writes the KV cache
               directly into the request's arena slot),
  * ``D<i>``  — decode layer i for ONE token, *batched with ragged per-row
               positions* across the merged sub-batch (each member joined
               at a different time — the ragged-decode situation the
               Pallas kernel targets),
  * ``head``  — unembed + greedy-sample the next token.

Cache arena (the serving hot path)
----------------------------------
Per-request caches live in a **preallocated, device-resident slot arena**:
at engine init, each layer gets one cache pytree with leading axis
``n_slots`` — time-axis leaves (``_TIME_AXIS_KEYS``: k/v/ckv/krope) are
``(n_slots, max_len, ...)``, recurrent/conv state leaves are
``(n_slots, ...)``. Slot lifecycle:

  * a request is **assigned a free slot lazily** at its first cache-touching
    node (prefill) and owns it for its lifetime,
  * prefill **writes into the slot in-place** inside the jitted layer fn
    (time leaves zero-padded to ``max_len`` first, so slot reuse never
    leaks a previous occupant's rows),
  * decode nodes **gather** member rows by a ``(B,)`` slot-index vector,
    run the batched block, and **scatter** updated rows back — on the
    Pallas ragged-attention path the kernel reads the arena directly via
    slot-indexed BlockSpecs and only the single new (k, v) token is
    scattered,
  * the slot is **released** when the request executes its final node (and
    idempotently again via ``Executor.on_finished`` from the server loop).

No per-dispatch ``jnp.stack`` over per-request cache pytrees, no full-cache
host round-trips: the per-token dispatch cost is O(B·d) for activations
instead of O(B·max_len·d_model) per layer for cache restacking (the arena
is additionally donated to each jitted fn, so the scatter updates it
in-place rather than copying all n_slots rows). Measured with
``benchmarks/engine_decode_bench.py`` (llama3.2-1b reduced, batch 8,
max_len=256, CPU backend): 63.3 ms/token seed restacking -> 17.4 ms/token
arena, a 3.6x speedup (see README §Serving). ``cache_mode="legacy"``
keeps the seed stack/unstack path for parity tests and benchmarking.

Token semantics are exact: the prompt's last token is fed as the first
decode-cycle input (prefill covers ``prompt[:-1]``), so every token is
processed exactly once. Decode nodes execute truly batched (stacked
activation rows + ragged ``pos``); prefill nodes run per-request (prompts
have unequal lengths — padding buys nothing on the CPU demo and the
simulator covers the batching economics).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.request import Request, SubBatch
from ..models import layers as L
from ..models.cost import _layer_kinds
from ..models.model import Model, RuntimeFlags, _index
from .server import Executor

# cache leaves whose leading (post-batch) axis is the KV time axis
_TIME_AXIS_KEYS = ("k", "v", "ckv", "krope")


def _is_time_leaf(path) -> bool:
    return str(getattr(path[-1], "key", "")) in _TIME_AXIS_KEYS


def _write_slot(arena, cache, slot):
    """Write one request's prefill cache into arena row ``slot`` (in-jit).

    ``cache`` leaves carry a batch=1 leading dim from the per-request
    prefill; time-axis leaves are zero-padded up to the arena's max_len so
    the whole row is overwritten (slot reuse cannot leak stale tokens —
    the padded region is masked at decode anyway, but zeroing keeps rows
    bit-identical to a fresh engine's).
    """
    def write(path, a, c):
        if c.ndim >= 1 and c.shape[0] == 1:
            c = c[0]                              # drop the batch=1 dim
        if _is_time_leaf(path):
            pad_n = a.shape[1] - c.shape[0]
            assert pad_n >= 0, (c.shape, a.shape)
            c = jnp.pad(c, [(0, pad_n)] + [(0, 0)] * (c.ndim - 1))
        return a.at[slot].set(c.astype(a.dtype))

    return jax.tree_util.tree_map_with_path(write, arena, cache)


class EngineState:
    """Mutable per-request execution state."""

    def __init__(self, prompt_tokens: np.ndarray):
        assert len(prompt_tokens) >= 2, "engine needs prompts of >= 2 tokens"
        self.prompt = jnp.asarray(prompt_tokens, jnp.int32)
        self.prefill_len = int(len(prompt_tokens) - 1)
        self.x: Optional[jax.Array] = None       # activations in flight
        self.caches: Dict[int, object] = {}      # legacy mode: layer -> cache
        self.generated: List[int] = []
        self.next_token: int = int(prompt_tokens[-1])
        self.pos: int = self.prefill_len         # next KV slot to write


class JaxEngine(Executor):
    """Executes workload nodes on a real (reduced) model.

    ``cache_mode``: "arena" (default) uses the persistent slot arena;
    "legacy" keeps per-request caches and restacks them per dispatch (the
    seed behavior — kept for parity tests and the decode benchmark).
    ``pallas``: route batched ragged decode attention through the Pallas
    kernel where the config allows (dense attention, no sliding window).
    Defaults to on for accelerator backends, off for CPU (interpret mode
    is functional but slow).
    """

    def __init__(self, cfg: ModelConfig, *, max_len: int = 512, seed: int = 0,
                 dtype=jnp.float32, n_slots: Optional[int] = None,
                 cache_mode: str = "arena", pallas: Optional[bool] = None):
        assert cache_mode in ("arena", "legacy"), cache_mode
        # explicit n_slots pins the arena (exhaustion raises); the default
        # starts at 32 slots and doubles on demand, so any admission policy
        # (max_batch defaults to 64) can't crash the engine mid-run
        self._auto_grow = n_slots is None
        if n_slots is None:
            n_slots = 32
        if pallas is None:
            # legacy mode is the seed-numerics baseline: never reroute its
            # decode through the Pallas kernel implicitly
            pallas = (cache_mode == "arena"
                      and jax.default_backend() != "cpu")
        self.cfg = cfg
        self.model = Model(cfg, RuntimeFlags(dtype=dtype,
                                             pallas_decode=pallas))
        self.params = self.model.init(jax.random.key(seed))
        self.kinds = _layer_kinds(cfg)
        self.max_len = max_len
        self.cache_mode = cache_mode
        self.states: Dict[int, EngineState] = {}
        self.nodes_executed = 0
        self._jit_cache: Dict[tuple, object] = {}
        # batched decode activations keyed by sub-batch membership: while a
        # merged batch advances in lockstep its (B, d) activation tensor is
        # reused across D-nodes / head without per-node stack + unstack;
        # rows are flushed back to per-request state when membership changes
        self._xbatch: Optional[tuple] = None     # (rids tuple, (B, d) array)
        # (B,) slot-index device vector, also keyed by membership: slots are
        # pinned for a request's lifetime, so the vector is invariant until
        # the sub-batch composition changes
        self._slotbatch: Optional[tuple] = None  # (rids tuple, (B,) array)
        self.n_slots = n_slots
        self._free_slots: List[int] = list(range(n_slots))
        self._slot: Dict[int, int] = {}          # rid -> slot
        if cache_mode == "arena":
            self.arena: List[object] = [
                self.model._init_layer_cache(kind, n_slots, max_len,
                                             window=None)
                for kind in self.kinds
            ]
        else:
            self.arena = []

    # ------------------------------------------------------------------
    # Request registration / slot lifecycle
    # ------------------------------------------------------------------
    def register(self, req: Request, prompt_tokens: np.ndarray):
        self.states[req.rid] = EngineState(prompt_tokens)

    def state(self, req: Request) -> EngineState:
        return self.states[req.rid]

    def slot_of(self, req: Request) -> int:
        """Arena slot owned by ``req`` (lazily assigned at first use)."""
        slot = self._slot.get(req.rid)
        if slot is None:
            if not self._free_slots:
                if not self._auto_grow:
                    raise RuntimeError(
                        f"cache arena exhausted: {self.n_slots} slots all "
                        f"held by live requests — raise "
                        f"JaxEngine(n_slots=...) above the policy's max "
                        f"concurrent batch size")
                self._grow_arena()
            slot = self._free_slots.pop(0)
            self._slot[req.rid] = slot
        return slot

    def _grow_arena(self):
        """Double the arena's slot capacity (rare; amortized O(1) per
        request — existing rows keep their slot ids, new rows are zero)."""
        old = self.n_slots
        self.arena = [
            jax.tree.map(lambda l: jnp.concatenate(
                [l, jnp.zeros_like(l)], axis=0), layer)
            for layer in self.arena
        ]
        self.n_slots = 2 * old
        self._free_slots.extend(range(old, self.n_slots))

    def release_slot(self, req: Request):
        """Return ``req``'s slot to the free list (idempotent)."""
        slot = self._slot.pop(req.rid, None)
        if slot is not None:
            self._free_slots.append(slot)

    @property
    def slots_in_use(self) -> int:
        return len(self._slot)

    def on_finished(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.release_slot(r)

    # ------------------------------------------------------------------
    # Batched-activation cache (arena mode)
    # ------------------------------------------------------------------
    def _flush_xbatch(self):
        if self._xbatch is not None:
            rids, x = self._xbatch
            for bi, rid in enumerate(rids):
                st = self.states.get(rid)
                if st is not None:
                    st.x = x[bi]
            self._xbatch = None

    def _batched_x(self, reqs, sts, fresh=None):
        """(rids, (B, d) activations) for the current membership; ``fresh``
        (decode-cycle entry embeddings) bypasses both cache and stack."""
        rids = tuple(r.rid for r in reqs)
        if self._xbatch is not None and self._xbatch[0] != rids:
            self._flush_xbatch()                  # preserve ex-members' rows
        if fresh is not None:
            x = fresh
        elif self._xbatch is not None:
            x = self._xbatch[1]
        else:
            x = jnp.stack([st.x for st in sts])
        return rids, x

    def _batched_slots(self, reqs, rids):
        if self._slotbatch is None or self._slotbatch[0] != rids:
            self._slotbatch = (rids, jnp.asarray(
                [self.slot_of(r) for r in reqs], jnp.int32))
        return self._slotbatch[1]

    # ------------------------------------------------------------------
    def _layer_params(self, i: int):
        cfg = self.cfg
        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            g, j = divmod(i, len(pat))
            if g < self.model.n_groups:
                return _index(self.params["blocks"], g)[f"b{j}_{pat[j]}"]
            return _index(self.params["tail"], i - self.model.n_groups * len(pat))
        return _index(self.params["blocks"], i)

    def _kind_window(self, i: int):
        cfg = self.cfg
        kind = self.kinds[i]
        if cfg.hybrid is not None:
            if kind == "attn":
                return "dense", cfg.hybrid.local_window
            return kind, None
        return ("dense" if kind == "attn" else kind), None

    def _node_meta(self, wl, node_id: str):
        """(phase, layer) for a node: NodeDesc metadata when present,
        engine node-id convention as fallback."""
        nd = wl.nodes.get(node_id) if wl is not None else None
        if nd is not None and getattr(nd, "phase", ""):
            return nd.phase, nd.layer
        if node_id == "emb":
            return "emb", -1
        if node_id == "head":
            return "head", -1
        if node_id[:1] in ("P", "D") and node_id[1:].isdigit():
            return ("prefill" if node_id[0] == "P" else "decode",
                    int(node_id[1:]))
        raise KeyError(f"unknown node {node_id!r}")

    # ------------------------------------------------------------------
    # Jitted node functions
    # ------------------------------------------------------------------
    def _fn_prefill(self, i: int):
        key = ("prefill", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, x):
                positions = jnp.arange(x.shape[1])[None, :]
                x, cache = self.model.apply_block_dense(
                    bp, x, kind, return_cache=True, window=window,
                    positions=positions)
                if isinstance(cache, tuple):      # moe: (kv_cache, aux)
                    cache = cache[0]
                return x, cache

            self._jit_cache[key] = jax.jit(fn)
        return self._jit_cache[key]

    def _fn_prefill_arena(self, i: int):
        key = ("prefill_arena", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, arena, x, slot):
                positions = jnp.arange(x.shape[1])[None, :]
                x, cache = self.model.apply_block_dense(
                    bp, x, kind, return_cache=True, window=window,
                    positions=positions)
                if isinstance(cache, tuple):      # moe: (kv_cache, aux)
                    cache = cache[0]
                return x, _write_slot(arena, cache, slot)

            # the donated arena is updated in-place instead of copying all
            # n_slots rows per dispatch (backends without donation support
            # fall back to a copy with a warning)
            self._jit_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._jit_cache[key]

    def _fn_decode(self, i: int):
        key = ("decode", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, x, cache, pos):
                return self.model.apply_block_decode(
                    bp, x, cache, pos, kind, window=window)

            self._jit_cache[key] = jax.jit(fn)
        return self._jit_cache[key]

    def _fn_decode_arena(self, i: int):
        key = ("decode_arena", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, arena, x, pos, slots):
                return self.model.apply_block_decode(
                    bp, x, arena, pos, kind, window=window, slots=slots)

            # the donated arena is updated in-place instead of copying all
            # n_slots rows per dispatch (backends without donation support
            # fall back to a copy with a warning)
            self._jit_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._jit_cache[key]

    def _fn_head(self):
        if "head" not in self._jit_cache:
            def fn(params, x):
                h = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
                logits = self.model.unembed(params, h)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)

            self._jit_cache["head"] = jax.jit(fn)
        return self._jit_cache["head"]

    # ------------------------------------------------------------------
    def execute(self, sb: SubBatch, node_id: str) -> float:
        t0 = time.perf_counter()
        reqs = sb.live_requests
        outs = []
        phase, i = self._node_meta(reqs[0].workload, node_id)
        if phase == "emb":
            for r in reqs:
                st = self.state(r)
                st.x = self.model.embed(
                    self.params, st.prompt[None, :st.prefill_len])
                outs.append(st.x)
        elif phase == "prefill":
            bp = self._layer_params(i)
            last = (i == len(self.kinds) - 1)
            if self.cache_mode == "arena":
                fn = self._fn_prefill_arena(i)
                for r in reqs:
                    st = self.state(r)
                    slot = self.slot_of(r)    # may grow the arena: resolve
                    st.x, self.arena[i] = fn(bp, self.arena[i], st.x, slot)
                    outs.append(st.x)
                    if last:                      # prefill done
                        st.x = None
            else:
                fn = self._fn_prefill(i)
                for r in reqs:
                    st = self.state(r)
                    st.x, cache = fn(bp, st.x)
                    st.caches[i] = self._pad_cache(cache, st.prefill_len)
                    outs.append(st.x)
                    if last:
                        st.x = None
        elif phase == "decode":
            bp = self._layer_params(i)
            sts = [self.state(r) for r in reqs]
            fresh = None
            if i == 0:
                toks = jnp.asarray([st.next_token for st in sts], jnp.int32)
                fresh = self.model.embed(self.params, toks)   # (B, d)
            pos = jnp.asarray([st.pos for st in sts], jnp.int32)
            if self.cache_mode == "arena":
                rids, x = self._batched_x(reqs, sts, fresh)
                fn = self._fn_decode_arena(i)
                slots = self._batched_slots(reqs, rids)
                x, self.arena[i] = fn(bp, self.arena[i], x, pos, slots)
                self._xbatch = (rids, x)
            else:
                if fresh is not None:
                    for bi, st in enumerate(sts):
                        st.x = fresh[bi]
                x = jnp.stack([st.x for st in sts])           # (B, d)
                fn = self._fn_decode(i)
                cache = jax.tree.map(lambda *ls: jnp.stack(ls),
                                     *[st.caches[i] for st in sts])
                x, new_cache = fn(bp, x, cache, pos)
                for bi, st in enumerate(sts):
                    st.caches[i] = jax.tree.map(lambda l: l[bi], new_cache)
                    st.x = x[bi]
            outs.append(x)
        elif phase == "head":
            fn = self._fn_head()
            sts = [self.state(r) for r in reqs]
            if self.cache_mode == "arena":
                rids, x = self._batched_x(reqs, sts)
                self._xbatch = (rids, x)
            else:
                x = jnp.stack([st.x for st in sts])
            toks = fn(self.params, x)
            outs.append(toks)
            toks = np.asarray(toks)
            for bi, st in enumerate(sts):
                st.next_token = int(toks[bi])
                st.generated.append(st.next_token)
                st.pos += 1
        else:
            raise KeyError(f"unknown node {node_id!r}")
        self.nodes_executed += 1
        for o in outs:
            jax.block_until_ready(o)
        # free arena slots of requests that just executed their final node
        # (on_finished() releases them too — both are idempotent — but this
        # covers direct engine driving without the server loop)
        for r in reqs:
            if r.idx == len(r.sequence) - 1:
                self.release_slot(r)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def _pad_cache(self, cache, prefill_len: int):
        """Legacy mode: prefill returns time-axis caches sized to the
        prompt; pad them to ``max_len`` so merged decode batches share one
        cache shape. Only leaves named in ``_TIME_AXIS_KEYS`` (k/v/ckv/
        krope) have a time axis; recurrent state/conv leaves pass through
        untouched."""

        def pad(path, leaf):
            if not _is_time_leaf(path):
                return leaf
            if leaf.ndim >= 2 and leaf.shape[0] == 1:
                leaf = leaf[0]                    # drop the batch=1 dim
            pad_n = self.max_len - leaf.shape[0]
            assert pad_n >= 0, (leaf.shape, self.max_len)
            return jnp.pad(leaf, [(0, pad_n)] + [(0, 0)] * (leaf.ndim - 1))

        padded = jax.tree_util.tree_map_with_path(pad, cache)
        # non-time leaves still carry the batch=1 dim — drop it
        return jax.tree_util.tree_map_with_path(
            lambda p, l: (l[0] if not _is_time_leaf(p) and l.ndim >= 1
                          and l.shape[0] == 1 else l),
            padded)
