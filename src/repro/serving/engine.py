"""Real-JAX node-level serving engine.

The discrete-event simulator (``server.py``) models latency analytically;
this engine executes the SAME policies against the ACTUAL model: every
``(sub_batch, node_id)`` the scheduler emits dispatches a jitted per-layer
function on device and mutates real request state (activations, KV caches,
generated tokens). It is the existence proof of the paper's claim that
node-level preemption needs no hardware support — preemption is just
"which jitted node fn we dispatch next" (DESIGN.md §3).

Node ids come from ``workload.from_model_config``:

  * ``emb``   — embed the prompt,
  * ``P<i>``  — prefill layer i over the prompt (builds the KV cache),
  * ``D<i>``  — decode layer i for ONE token, *batched with ragged per-row
               positions* across the merged sub-batch (each member joined
               at a different time — the ragged-decode situation the
               Pallas kernel targets),
  * ``head``  — unembed + greedy-sample the next token.

Token semantics are exact: the prompt's last token is fed as the first
decode-cycle input (prefill covers ``prompt[:-1]``), so every token is
processed exactly once. Decode nodes execute truly batched (stacked rows +
ragged ``pos``); prefill nodes run per-request (prompts have unequal
lengths — padding buys nothing on the CPU demo and the simulator covers
the batching economics). Per-request per-layer caches are stored unstacked
and stacked/unstacked around each batched dispatch.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.request import Request, SubBatch
from ..models import layers as L
from ..models.cost import _layer_kinds
from ..models.model import Model, RuntimeFlags, _index
from .server import Executor

# cache leaves whose leading (post-batch) axis is the KV time axis
_TIME_AXIS_KEYS = ("k", "v", "ckv", "krope")


class EngineState:
    """Mutable per-request execution state."""

    def __init__(self, prompt_tokens: np.ndarray):
        assert len(prompt_tokens) >= 2, "engine needs prompts of >= 2 tokens"
        self.prompt = jnp.asarray(prompt_tokens, jnp.int32)
        self.prefill_len = int(len(prompt_tokens) - 1)
        self.x: Optional[jax.Array] = None       # activations in flight
        self.caches: Dict[int, object] = {}      # layer -> cache pytree
        self.generated: List[int] = []
        self.next_token: int = int(prompt_tokens[-1])
        self.pos: int = self.prefill_len         # next KV slot to write


class JaxEngine(Executor):
    """Executes workload nodes on a real (reduced) model."""

    def __init__(self, cfg: ModelConfig, *, max_len: int = 512, seed: int = 0,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.model = Model(cfg, RuntimeFlags(dtype=dtype))
        self.params = self.model.init(jax.random.key(seed))
        self.kinds = _layer_kinds(cfg)
        self.max_len = max_len
        self.states: Dict[int, EngineState] = {}
        self.nodes_executed = 0
        self._jit_cache: Dict[tuple, object] = {}

    # ------------------------------------------------------------------
    def register(self, req: Request, prompt_tokens: np.ndarray):
        self.states[req.rid] = EngineState(prompt_tokens)

    def state(self, req: Request) -> EngineState:
        return self.states[req.rid]

    # ------------------------------------------------------------------
    def _layer_params(self, i: int):
        cfg = self.cfg
        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            g, j = divmod(i, len(pat))
            if g < self.model.n_groups:
                return _index(self.params["blocks"], g)[f"b{j}_{pat[j]}"]
            return _index(self.params["tail"], i - self.model.n_groups * len(pat))
        return _index(self.params["blocks"], i)

    def _kind_window(self, i: int):
        cfg = self.cfg
        kind = self.kinds[i]
        if cfg.hybrid is not None:
            if kind == "attn":
                return "dense", cfg.hybrid.local_window
            return kind, None
        return ("dense" if kind == "attn" else kind), None

    # ------------------------------------------------------------------
    def _fn_prefill(self, i: int):
        key = ("prefill", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, x):
                positions = jnp.arange(x.shape[1])[None, :]
                x, cache = self.model.apply_block_dense(
                    bp, x, kind, return_cache=True, window=window,
                    positions=positions)
                if isinstance(cache, tuple):      # moe: (kv_cache, aux)
                    cache = cache[0]
                return x, cache

            self._jit_cache[key] = jax.jit(fn)
        return self._jit_cache[key]

    def _fn_decode(self, i: int):
        key = ("decode", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, x, cache, pos):
                return self.model.apply_block_decode(
                    bp, x, cache, pos, kind, window=window)

            self._jit_cache[key] = jax.jit(fn)
        return self._jit_cache[key]

    def _fn_head(self):
        if "head" not in self._jit_cache:
            def fn(params, x):
                h = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
                logits = self.model.unembed(params, h)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)

            self._jit_cache["head"] = jax.jit(fn)
        return self._jit_cache[key] if False else self._jit_cache["head"]

    # ------------------------------------------------------------------
    def execute(self, sb: SubBatch, node_id: str) -> float:
        t0 = time.perf_counter()
        reqs = sb.live_requests
        outs = []
        if node_id == "emb":
            for r in reqs:
                st = self.state(r)
                st.x = self.model.embed(
                    self.params, st.prompt[None, :st.prefill_len])
                outs.append(st.x)
        elif node_id.startswith("P"):
            i = int(node_id[1:])
            fn = self._fn_prefill(i)
            bp = self._layer_params(i)
            for r in reqs:
                st = self.state(r)
                st.x, cache = fn(bp, st.x)
                st.caches[i] = self._pad_cache(cache, st.prefill_len)
                outs.append(st.x)
                if i == len(self.kinds) - 1:      # prefill done
                    st.x = None
        elif node_id.startswith("D"):
            i = int(node_id[1:])
            fn = self._fn_decode(i)
            bp = self._layer_params(i)
            sts = [self.state(r) for r in reqs]
            if i == 0:
                for st in sts:
                    st.x = self.model.embed(
                        self.params,
                        jnp.asarray([st.next_token], jnp.int32))[0]
            x = jnp.stack([st.x for st in sts])                  # (B, d)
            cache = jax.tree.map(lambda *ls: jnp.stack(ls),
                                 *[st.caches[i] for st in sts])
            pos = jnp.asarray([st.pos for st in sts], jnp.int32)
            x, new_cache = fn(bp, x, cache, pos)
            for bi, st in enumerate(sts):
                st.x = x[bi]
                st.caches[i] = jax.tree.map(lambda l: l[bi], new_cache)
            outs.append(x)
        elif node_id == "head":
            fn = self._fn_head()
            sts = [self.state(r) for r in reqs]
            x = jnp.stack([st.x for st in sts])
            toks = fn(self.params, x)
            outs.append(toks)
            toks = np.asarray(toks)
            for bi, st in enumerate(sts):
                st.next_token = int(toks[bi])
                st.generated.append(st.next_token)
                st.pos += 1
        else:
            raise KeyError(f"unknown node {node_id!r}")
        self.nodes_executed += 1
        for o in outs:
            jax.block_until_ready(o)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def _pad_cache(self, cache, prefill_len: int):
        """Prefill returns time-axis caches sized to the prompt; pad them to
        ``max_len`` so merged decode batches share one cache shape. Only
        leaves named in ``_TIME_AXIS_KEYS`` (k/v/ckv/krope) have a time
        axis; recurrent state/conv leaves pass through untouched."""

        def pad(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name not in _TIME_AXIS_KEYS:
                return leaf
            if leaf.ndim >= 2 and leaf.shape[0] == 1:
                leaf = leaf[0]                    # drop the batch=1 dim
            pad_n = self.max_len - leaf.shape[0]
            assert pad_n >= 0, (leaf.shape, self.max_len)
            return jnp.pad(leaf, [(0, pad_n)] + [(0, 0)] * (leaf.ndim - 1))

        padded = jax.tree_util.tree_map_with_path(pad, cache)
        # non-time leaves still carry the batch=1 dim — drop it
        return jax.tree_util.tree_map_with_path(
            lambda p, l: (l[0] if str(getattr(p[-1], "key", ""))
                          not in _TIME_AXIS_KEYS and l.ndim >= 1
                          and l.shape[0] == 1 else l),
            padded)
