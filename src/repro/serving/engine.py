"""Real-JAX node-level serving engine: slot-arena caches + fused node runs.

The discrete-event simulator (``server.py``) models latency analytically;
this engine executes the SAME policies against the ACTUAL model. Scheduling
stays node-granular — every ``(sub_batch, node_id)`` the scheduler emits is
a valid dispatch — but execution is *run*-granular: when a policy commits a
run of consecutive nodes (the run-commit contract, ``core.policies``), the
engine fuses the whole run into a handful of jitted dispatches instead of
one Python→device round-trip per layer. Decide per node, execute per run.

Node ids come from ``workload.from_model_config`` (each ``NodeDesc``
carries ``phase``/``layer`` metadata the dispatcher keys on):

  * ``emb``   — embed the prompt,
  * ``P<i>``  — prefill layer i over the prompt (writes the KV cache
               directly into the request's arena slot),
  * ``D<i>``  — decode layer i for ONE token, *batched with ragged per-row
               positions* across the merged sub-batch,
  * ``head``  — unembed + greedy-sample the next token.

Cache arena (PR 1; now paged + reclaimable)
-------------------------------------------
Per-request caches live in a preallocated, device-resident slot arena;
requests own a lazily-assigned slot for their lifetime, prefill writes
into the slot in-jit, decode gathers/scatters rows by a ``(B,)`` slot
vector, and slots are released on completion (idempotently again via
``Backend.on_finished``). The arena is *paged*: it doubles on demand up
to an optional ``max_slots`` memory cap and — unless pinned —
**shrinks back** when occupancy drops (live slots are compacted below
the watermark, the slot axis sliced down; bit-exact, see
``_shrink_arena``), so a burst no longer pins peak device memory
forever. ``memory_stats()`` reports slots live/free and actual resident
bytes for SLA-aware, memory-aware admission upstream. Storage is now **per-span, flat-indexed**:
consecutive same-(kind, window) layers form a span whose arena pytree
folds the layer axis into the slot axis — leaves are
``(span_len * n_slots, max_len, ...)`` for time-axis keys (k/v/ckv/krope)
and ``(span_len * n_slots, ...)`` for recurrent state, with layer k's
batch rows at ``slots + k * n_slots``. A whole span is then one
``lax.scan`` over stacked params with the arena riding the carry (aliased
in place by XLA): each layer step gathers/scatters ONLY its B live rows —
scanning the arena as scan inputs/outputs instead would materialize two
full per-layer cache copies per step. Homogeneous models are a single
span; hybrid models get maximal same-kind spans (their span param stacks
duplicate block params once at init — the price of scanned dispatch over
a heterogeneous stack).

Fused node-run execution (this PR's hot path)
---------------------------------------------
``execute_run(sb, node_ids)`` parses a committed run into phase chunks and
dispatches each chunk as ONE jitted call:

  * **decode megasteps** — a chunk ``D_i..D_j[+head]`` runs as a single
    jitted ``lax.scan`` over the stacked span params + span arenas (the
    whole arena list is passed and donated as one pytree), with the head
    (final norm + unembed + argmax) folded into the same dispatch. A
    multi-cycle run loops cycle megasteps *without host sync*: each
    cycle's sampled tokens stay on device and feed the next cycle's
    embedding directly.
  * **bucketed batched prefill** — ``emb + P0..Pk`` prefills all members
    of a sub-batch together: prompts are right-padded to power-of-two
    length buckets (capped at ``max_len``) and same-bucket requests are
    batched; causal attention masks the padding (a valid row only ever
    attends to valid rows), so cache rows are bit-identical to isolated
    prefill, and rows past a request's true length are overwritten by
    decode before they can be read. Enabled for attention-family stacks
    (dense/MLA); MoE/SSM/recurrent stacks prefill per-request but still
    fused across layers in one scanned dispatch.
  * **batch-size bucketing** — decode batches are padded to the next
    power of two so recompiles are bounded by O(log max_batch) instead of
    one per distinct membership size. Padded rows carry an out-of-bounds
    slot sentinel: their arena scatters are dropped (mode="drop"), their
    gathers are clamped, and their outputs discarded on host.
  * **async dispatch** — no per-node ``block_until_ready``; dispatches
    inside a run chain on device and the engine synchronizes ONCE at the
    run boundary (the scheduler-visible point), so the server clock
    measures run latency, not per-node latency.

``execute(sb, node_id)`` (single-node dispatch, one blocking device call
per node) remains fully supported — it is the degenerate run and the
bit-exactness reference. ``cache_mode="legacy"`` keeps the seed
stack/unstack path for parity tests; generated tokens are bit-exact
across legacy / arena / fused-run for the same trace (enforced by
``tests/test_engine_arena.py`` and ``benchmarks/engine_decode_bench.py``).

Token semantics are exact: the prompt's last token is fed as the first
decode-cycle input (prefill covers ``prompt[:-1]``), so every token is
processed exactly once.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.request import Request, SubBatch
from ..models import layers as L
from ..models.cost import _layer_kinds
from ..models.model import Model, RuntimeFlags, _index, _stack
from .backend import Backend, BackendOOMError

# cache leaves whose leading (post-batch) axis is the KV time axis
_TIME_AXIS_KEYS = ("k", "v", "ckv", "krope")

# slot sentinel for batch-bucket padding rows: far out of bounds for any
# arena size, so scatters drop and clamped gathers read an arbitrary live
# row (output discarded). Must never be reachable by arena growth.
_PAD_SLOT = np.int32(2 ** 30)


def _is_time_leaf(path) -> bool:
    return str(getattr(path[-1], "key", "")) in _TIME_AXIS_KEYS


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


class EngineState:
    """Mutable per-request execution state."""

    def __init__(self, prompt_tokens: np.ndarray):
        if len(prompt_tokens) < 2:
            raise ValueError(
                f"engine needs prompts of >= 2 tokens (teacher-forced "
                f"prefill predicts token i+1 from token i), got "
                f"{len(prompt_tokens)}")
        self.prompt = jnp.asarray(prompt_tokens, jnp.int32)
        self.prompt_np = np.asarray(prompt_tokens, np.int32)
        self.prefill_len = int(len(prompt_tokens) - 1)
        self.x: Optional[jax.Array] = None       # activations in flight
        self.caches: Dict[int, object] = {}      # legacy mode: layer -> cache
        self.generated: List[int] = []
        self.next_token: int = int(prompt_tokens[-1])
        self.pos: int = self.prefill_len         # next KV slot to write


class JaxEngine(Backend):
    """Executes workload nodes on a real (reduced) model.

    One engine holds ONE model's parameters and KV arena, so the
    ``model`` key threaded through the Backend contract is accepted and
    ignored — multi-tenant sessions put one engine per registered model
    behind a :class:`~repro.serving.backend.MultiBackend`, which routes
    on the key before it gets here.

    ``cache_mode``: "arena" (default) uses the persistent slot arena;
    "legacy" keeps per-request caches and restacks them per dispatch (the
    seed behavior — kept for parity tests and the decode benchmark).
    ``fused``: fuse committed multi-node runs into scanned megastep
    dispatches (defaults to on for arena mode; ``False`` forces one
    dispatch per node even under the run-commit server loop — the PR-1
    arena baseline).
    ``pallas``: route batched ragged decode attention through the Pallas
    kernel where the config allows (dense attention, no sliding window).
    Defaults to on for accelerator backends, off for CPU (interpret mode
    is functional but slow).
    """

    def __init__(self, cfg: ModelConfig, *, max_len: int = 512, seed: int = 0,
                 dtype=jnp.float32, n_slots: Optional[int] = None,
                 max_slots: Optional[int] = None,
                 min_slots: Optional[int] = None,
                 auto_shrink: Optional[bool] = None,
                 cache_mode: str = "arena", pallas: Optional[bool] = None,
                 fused: Optional[bool] = None):
        if cache_mode not in ("arena", "legacy"):
            raise ValueError(f"cache_mode must be 'arena' or 'legacy', "
                             f"got {cache_mode!r}")
        # arena sizing: explicit n_slots WITHOUT max_slots pins the arena
        # (exhaustion raises — the seed behavior); otherwise the arena is
        # *paged*: it starts at n_slots (or min_slots, default 32), doubles
        # on demand up to max_slots (None = unbounded), and — when
        # auto_shrink is on (the paged default) — compacts+halves back
        # toward min_slots as occupancy drops, so one burst no longer pins
        # peak device memory forever.
        pinned = n_slots is not None and max_slots is None
        if n_slots is None:
            n_slots = min_slots if min_slots is not None else 32
            if max_slots is not None:        # default start clamps to the cap
                n_slots = min(n_slots, max_slots)
        if max_slots is not None and max_slots < n_slots:
            raise ValueError(
                f"max_slots ({max_slots}) must be >= the starting arena "
                f"size n_slots ({n_slots})")
        self.max_slots = max_slots
        self._min_slots = min_slots if min_slots is not None else n_slots
        self._auto_grow = not pinned
        self._auto_shrink = (not pinned) if auto_shrink is None else auto_shrink
        self.n_grows = 0
        self.n_shrinks = 0
        if pallas is None:
            # legacy mode is the seed-numerics baseline: never reroute its
            # decode through the Pallas kernel implicitly
            pallas = (cache_mode == "arena"
                      and jax.default_backend() != "cpu")
        self.cfg = cfg
        self.model = Model(cfg, RuntimeFlags(dtype=dtype,
                                             pallas_decode=pallas))
        self.params = self.model.init(jax.random.key(seed))
        self.kinds = _layer_kinds(cfg)
        self.max_len = max_len
        self.cache_mode = cache_mode
        self.fused = (cache_mode == "arena") if fused is None else fused
        self.states: Dict[int, EngineState] = {}
        self.nodes_executed = 0
        self.runs_executed = 0
        self._jit_cache: Dict[tuple, object] = {}
        # hot-path sanitizer counters (Backend.sanitizer_stats): retraces
        # are counted by a Python-side effect at the top of every jitted
        # body (it only executes while JAX traces — i.e. per XLA compile);
        # host syncs count run-boundary synchronization EVENTS (the whole
        # fused-run epilogue is one event)
        self._san_retraces = 0
        self._san_host_syncs = 0
        self._san_max_syncs_per_run = 0
        # batched decode activations keyed by sub-batch membership: while a
        # merged batch advances in lockstep its (B, d) activation tensor is
        # reused across D-nodes / head without per-node stack + unstack;
        # rows are flushed back to per-request state when membership changes
        self._xbatch: Optional[tuple] = None     # (rids tuple, (B, d) array)
        # (B,) slot-index device vector, also keyed by membership: slots are
        # pinned for a request's lifetime, so the vector is invariant until
        # the sub-batch composition changes ((rids, padded_B, array))
        self._slotbatch: Optional[tuple] = None
        # device-resident (Bp,) position / last-token vectors carried across
        # fused runs (keyed by (rids, Bp)): while membership is stable a new
        # run needs NO host->device upload — pos advances by a lazy device
        # add, tokens chain from the previous head. Host state (st.pos /
        # st.next_token) stays authoritative; any membership change or
        # single-node dispatch invalidates and rebuilds from it.
        self._posbatch: Optional[tuple] = None
        self._tokbatch: Optional[tuple] = None
        self._chunk_cache: Dict[tuple, list] = {}
        self.n_slots = n_slots
        self._free_slots: deque = deque(range(n_slots))
        self._slot: Dict[int, int] = {}          # rid -> slot
        # maximal same-(kind, window) layer spans; arenas + param stacks
        # are stored per span so a span is one lax.scan
        spans: List[tuple] = []
        for i in range(len(self.kinds)):
            kind, window = self._kind_window(i)
            if spans and spans[-1][0] == kind and spans[-1][1] == window:
                spans[-1] = (kind, window, spans[-1][2], i)
            else:
                spans.append((kind, window, i, i))
        self._spans = spans
        self._layer_loc = {}
        for si, (_, _, lo, hi) in enumerate(spans):
            for i in range(lo, hi + 1):
                self._layer_loc[i] = (si, i - lo)
        if cfg.hybrid is None:
            # homogeneous stack: params are already stacked (L, ...)
            self._span_params = [self.params["blocks"]]
        else:
            self._span_params = [
                _stack([self._layer_params(i) for i in range(lo, hi + 1)])
                for (_, _, lo, hi) in spans
            ]
        # span arenas in FLAT layout: the layer axis is folded into the slot
        # axis — leaves are (span_len * n_slots, ...) and layer k of a span
        # owns rows [k * n_slots, (k+1) * n_slots). Fused span scans thread
        # the arena through the scan carry (aliased in place) and address
        # layer k's batch rows as ``slots + k * n_slots`` — only the B live
        # rows are ever gathered/scattered, never a full layer slice.
        self._offs_cache: tuple = (None, None)    # n_slots -> per-span offs
        if cache_mode == "arena":
            self.arenas: List[object] = []
            for (kind, window, lo, hi) in spans:
                one = self.model._init_layer_cache(self.kinds[lo], n_slots,
                                                   max_len, window=None)
                span_len = hi - lo + 1
                self.arenas.append(jax.tree.map(
                    lambda l: jnp.zeros((span_len * l.shape[0],)
                                        + l.shape[1:], l.dtype), one))
        else:
            self.arenas = []

    # ------------------------------------------------------------------
    # Request registration / slot lifecycle
    # ------------------------------------------------------------------
    def register(self, req: Request, prompt_tokens: np.ndarray):
        self.states[req.rid] = EngineState(prompt_tokens)

    def prepare(self, model, req: Request, rng, prompt_tokens=None):
        """Backend-contract hook (ServingSession.submit): register the
        request's prompt — the supplied tokens, or a synthetic prompt of
        ``req.prompt_len`` sampled from ``rng`` (the session's seeded
        generator) when none is given. Idempotent for pre-registered
        requests (explicit ``register`` calls keep working)."""
        if req.rid in self.states:
            return
        if prompt_tokens is None:
            prompt_tokens = rng.integers(2, self.cfg.vocab_size,
                                         size=max(2, req.prompt_len))
        self.register(req, np.asarray(prompt_tokens))

    def token_count(self, model, req: Request) -> int:
        st = self.states.get(req.rid)
        return (len(st.generated) if st is not None
                else super().token_count(model, req))

    def tokens(self, model, req: Request):
        st = self.states.get(req.rid)
        return st.generated if st is not None else None

    def state(self, req: Request) -> EngineState:
        return self.states[req.rid]

    def slot_of(self, req: Request) -> int:
        """Arena slot owned by ``req`` (lazily assigned at first use)."""
        slot = self._slot.get(req.rid)
        if slot is None:
            if not self._free_slots:
                if not self._auto_grow:
                    # BackendOOMError subclasses RuntimeError: legacy
                    # catches keep working, fault-aware sessions can
                    # retry/fail the victims instead of crashing the loop
                    raise BackendOOMError(
                        f"cache arena exhausted: {self.n_slots} slots all "
                        f"held by live requests — raise "
                        f"JaxEngine(n_slots=...) above the policy's max "
                        f"concurrent batch size")
                self._grow_arena()
            slot = self._free_slots.popleft()
            self._slot[req.rid] = slot
        return slot

    def _grow_arena(self):
        """Widen the arena's slot capacity (rare; amortized O(1) per
        request — existing rows keep their slot ids, new rows are zero).
        Flat layout: unfold the layer axis, widen the slot axis, refold.
        Doubles, capped at ``max_slots``; at the cap, growth raises the
        same arena-exhausted error a pinned arena does (memory-aware
        admission is what keeps live requests under the cap)."""
        old = self.n_slots
        new = 2 * old if self.max_slots is None else min(2 * old,
                                                         self.max_slots)
        if new <= old:
            raise BackendOOMError(
                f"cache arena exhausted at its memory cap: all "
                f"{self.n_slots} slots (max_slots={self.max_slots}) held "
                f"by live requests — raise JaxEngine(max_slots=...) or "
                f"enable memory-aware admission so the scheduler defers "
                f"work instead of overcommitting device memory")
        # padded-row scatters use the _PAD_SLOT sentinel: growth must never
        # bring a real row index into the sentinel's range, or a padding
        # row's dropped scatter would silently alias a live slot
        if new >= _PAD_SLOT:
            raise RuntimeError(
                f"arena growth to {new} slots would reach the padded-row "
                f"sentinel (_PAD_SLOT={int(_PAD_SLOT)})")

        def grow(l):
            span_len = l.shape[0] // old
            r = l.reshape(span_len, old, *l.shape[1:])
            z = jnp.zeros((span_len, new - old) + l.shape[1:], l.dtype)
            return jnp.concatenate([r, z], axis=1).reshape(
                span_len * new, *l.shape[1:])

        self.arenas = [jax.tree.map(grow, span) for span in self.arenas]
        self.n_slots = new
        self.n_grows += 1
        self._free_slots.extend(range(old, self.n_slots))

    def _maybe_shrink(self):
        """Reclaim arena memory when occupancy has dropped: compact live
        slots below the target watermark and slice the arena down to it.

        Fires only when capacity exceeds TWICE the target — the target
        itself keeps a doubling of headroom above the live set
        (``pow2(2 * live)``, floored at ``min_slots``) — so a stable
        working set never thrashes grow/shrink, while a drained burst
        returns capacity (and ``memory_stats().bytes_resident``) to within
        2x of steady-state occupancy."""
        if (not self._auto_shrink or self.cache_mode != "arena"
                or not self.arenas):
            return
        live = len(self._slot)
        target = max(_pow2(2 * live) if live else 1, self._min_slots)
        if target * 2 <= self.n_slots:
            self._shrink_arena(target)

    def _shrink_arena(self, target: int):
        """Compact live slots below ``target`` (relocating their rows in
        every span arena) and halve+ the arena down to ``target`` slots.

        Bit-exact by construction: relocation copies rows verbatim, the
        flat layout (layer k at ``slot + k * n_slots``) is re-folded at
        the new width, and every membership-keyed device cache holding
        slot ids is invalidated. Eager (unjitted) dispatch — reclamation
        is rare and off the decode hot path; the next fused dispatch
        retraces once for the new arena shape, exactly as growth does."""
        old = self.n_slots
        if not (target < old and len(self._slot) <= target):
            raise RuntimeError(
                f"_shrink_arena precondition violated: target={target} "
                f"must be < current {old} slots and hold all "
                f"{len(self._slot)} live slots")
        # host-side relocation plan: live slots >= target move into the
        # lowest free slots < target (enough exist: live <= target)
        moving = sorted(s for s in self._slot.values() if s >= target)
        free_low = sorted(s for s in self._free_slots if s < target)
        dst_of = dict(zip(moving, free_low))
        for rid, s in self._slot.items():
            if s in dst_of:
                self._slot[rid] = dst_of[s]
        src_np = np.fromiter(dst_of.keys(), np.int32, len(dst_of))
        dst_np = np.fromiter(dst_of.values(), np.int32, len(dst_of))
        for si, (_, _, lo, hi) in enumerate(self._spans):
            span_len = hi - lo + 1
            offs = np.arange(span_len, dtype=np.int32) * old
            src = (src_np[None, :] + offs[:, None]).ravel()
            dst = (dst_np[None, :] + offs[:, None]).ravel()

            def compact(l):
                if len(src):
                    l = l.at[dst].set(l[src])
                r = l.reshape(span_len, old, *l.shape[1:])
                return r[:, :target].reshape(span_len * target, *l.shape[1:])

            self.arenas[si] = jax.tree.map(compact, self.arenas[si])
        self.n_slots = target
        self.n_shrinks += 1
        used = set(self._slot.values())
        self._free_slots = deque(s for s in range(target) if s not in used)
        # slot ids moved: the membership-keyed slot vector is stale (pos /
        # token vectors carry no slot ids and stay valid)
        self._slotbatch = None

    def _offs(self):
        """Per-span device vectors of layer row offsets (k * n_slots) in
        the flat arena layout; rebuilt only when the arena grows."""
        if self._offs_cache[0] != self.n_slots:
            self._offs_cache = (self.n_slots, [
                jnp.asarray(np.arange(hi - lo + 1, dtype=np.int32)
                            * self.n_slots)
                for (_, _, lo, hi) in self._spans
            ])
        return self._offs_cache[1]

    def release_slot(self, req: Request):
        """Return ``req``'s slot to the free pool (idempotent); reclaims
        arena capacity when occupancy has dropped far enough."""
        self._release_slots([req])

    def _release_slots(self, reqs: Sequence[Request]):
        """Release a whole batch of slots, then reclaim ONCE — a draining
        batch must not cascade through intermediate shrink sizes (each a
        full-arena copy that the next release would discard)."""
        released = False
        for r in reqs:
            slot = self._slot.pop(r.rid, None)
            if slot is not None:
                self._free_slots.append(slot)
                released = True
        if released:
            self._maybe_shrink()

    @property
    def slots_in_use(self) -> int:
        return len(self._slot)

    def memory_stats(self, model=None):
        """Arena accounting: slots live/free at current capacity plus the
        actual device-resident bytes (every span arena leaf). One engine
        is one pool — multi-tenant sessions see per-model pools through
        the :class:`~repro.serving.backend.MultiBackend` mux."""
        from .backend import MemoryStats
        total_bytes = sum(l.nbytes for span in self.arenas
                          for l in jax.tree.leaves(span))
        return MemoryStats(
            slots_total=self.n_slots,
            slots_live=len(self._slot),
            slots_free=len(self._free_slots),
            bytes_resident=int(total_bytes),
            bytes_per_slot=total_bytes / max(1, self.n_slots),
            max_slots=self.max_slots,
            pool=id(self))

    def sanitizer_stats(self, model=None):
        """Hot-path sanitizer snapshot: committed runs, run-boundary host
        sync events, and actual jit traces (= XLA compiles). Steady-state
        fused decode must show ``host_syncs`` growing at most one per run
        and ``retraces`` not growing at all — the dynamic counterpart of
        the ``sync-point`` / ``retrace-hazard`` static checkers."""
        from .backend import SanitizerStats
        return SanitizerStats(
            runs=self.runs_executed,
            host_syncs=self._san_host_syncs,
            retraces=self._san_retraces,
            max_syncs_per_run=self._san_max_syncs_per_run)

    def _note_trace(self):
        """Called from INSIDE jitted bodies: executes only at trace time,
        so each call is exactly one retrace/compile."""
        self._san_retraces += 1

    def on_finished(self, model, reqs: Sequence[Request]) -> None:
        self._release_slots(reqs)

    def reset_request(self, model, req: Request) -> None:
        """Fault recovery: discard the request's device-side progress.

        The membership-keyed device caches are invalidated FIRST and
        without flushing — the in-flight activations/positions/tokens
        belong to the faulted (void) run, and an identical-rids batch
        re-forming after the retry must never read them back. Then the
        KV slot returns to the free pool (idempotent; survivors'
        slots are untouched) and the host-side EngineState rewinds to
        its post-``prepare`` point: prompt intact, caches/activations/
        generated tokens gone, so the retry replays prefill from node 0
        and regenerates the same tokens bit-exactly."""
        rid = req.rid
        if self._xbatch is not None and rid in self._xbatch[0]:
            self._xbatch = None
        if self._slotbatch is not None and rid in self._slotbatch[0]:
            self._slotbatch = None
        if self._posbatch is not None and rid in self._posbatch[0][0]:
            self._posbatch = None
        if self._tokbatch is not None and rid in self._tokbatch[0][0]:
            self._tokbatch = None
        self._release_slots([req])
        st = self.states.get(rid)
        if st is not None:
            st.x = None
            st.caches = {}
            st.generated = []
            st.next_token = int(st.prompt_np[-1])
            st.pos = st.prefill_len

    def release_request(self, model, req: Request) -> None:
        """Drop the request's host-side EngineState (prompt, generated
        tokens, activations) once the caller is done with its results —
        wired through ``ServingSession.release`` so long-lived online
        sessions don't accumulate per-request state forever."""
        self.release_slot(req)
        self.states.pop(req.rid, None)

    # ------------------------------------------------------------------
    # Batched-activation cache (arena mode)
    # ------------------------------------------------------------------
    def _flush_xbatch(self):
        if self._xbatch is not None:
            rids, x = self._xbatch
            for bi, rid in enumerate(rids):
                st = self.states.get(rid)
                if st is not None:
                    st.x = x[bi]
            self._xbatch = None

    def _batched_x(self, reqs, sts, fresh=None):
        """(rids, (B, d) activations) for the current membership; ``fresh``
        (decode-cycle entry embeddings) bypasses both cache and stack."""
        rids = tuple(r.rid for r in reqs)
        if self._xbatch is not None and self._xbatch[0] != rids:
            self._flush_xbatch()                  # preserve ex-members' rows
        if fresh is not None:
            x = fresh
        elif self._xbatch is not None:
            x = self._xbatch[1]
        else:
            x = jnp.stack([st.x for st in sts])
        return rids, x

    def _batched_slots(self, reqs, rids, padded_to: Optional[int] = None):
        """(B,)-or-(Bp,) slot vector for the membership; padding rows get
        the out-of-bounds sentinel (scatters dropped, gathers clamped)."""
        Bp = padded_to or len(reqs)
        if self._slotbatch is None or self._slotbatch[0] != rids \
                or self._slotbatch[1] != Bp:
            slots = [self.slot_of(r) for r in reqs]
            slots += [_PAD_SLOT] * (Bp - len(slots))
            self._slotbatch = (rids, Bp, jnp.asarray(slots, jnp.int32))
        return self._slotbatch[2]

    # ------------------------------------------------------------------
    def _layer_params(self, i: int):
        cfg = self.cfg
        if cfg.hybrid is not None:
            pat = cfg.hybrid.block_pattern
            g, j = divmod(i, len(pat))
            if g < self.model.n_groups:
                return _index(self.params["blocks"], g)[f"b{j}_{pat[j]}"]
            return _index(self.params["tail"], i - self.model.n_groups * len(pat))
        return _index(self.params["blocks"], i)

    def _kind_window(self, i: int):
        cfg = self.cfg
        kind = self.kinds[i]
        if cfg.hybrid is not None:
            if kind == "attn":
                return "dense", cfg.hybrid.local_window
            return kind, None
        return ("dense" if kind == "attn" else kind), None

    def _node_meta(self, wl, node_id: str):
        """(phase, layer) for a node: NodeDesc metadata when present,
        engine node-id convention as fallback."""
        nd = wl.nodes.get(node_id) if wl is not None else None
        if nd is not None and getattr(nd, "phase", ""):
            return nd.phase, nd.layer
        if node_id == "emb":
            return "emb", -1
        if node_id == "head":
            return "head", -1
        if node_id[:1] in ("P", "D") and node_id[1:].isdigit():
            return ("prefill" if node_id[0] == "P" else "decode",
                    int(node_id[1:]))
        raise KeyError(f"unknown node {node_id!r}")

    # ------------------------------------------------------------------
    # Jitted node functions (single-node dispatch)
    # ------------------------------------------------------------------
    def _fn_prefill(self, i: int):
        key = ("prefill", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, x):
                self._note_trace()
                positions = jnp.arange(x.shape[1])[None, :]
                x, cache = self.model.apply_block_dense(
                    bp, x, kind, return_cache=True, window=window,
                    positions=positions)
                if isinstance(cache, tuple):      # moe: (kv_cache, aux)
                    cache = cache[0]
                return x, cache

            self._jit_cache[key] = jax.jit(fn)
        return self._jit_cache[key]

    def _fn_prefill_arena(self, si: int):
        """Per-node prefill into arena span ``si``; the flat row index
        ``slot + k * n_slots`` is a traced scalar so all span layers share
        one compiled fn."""
        key = ("prefill_arena", si)
        if key not in self._jit_cache:
            kind, window, _, _ = self._spans[si]

            def fn(bp, arena, x, row):
                self._note_trace()
                positions = jnp.arange(x.shape[1])[None, :]
                x, cache = self.model.apply_block_dense(
                    bp, x, kind, return_cache=True, window=window,
                    positions=positions)
                if isinstance(cache, tuple):      # moe: (kv_cache, aux)
                    cache = cache[0]

                def write(path, a, c):
                    if c.ndim >= 1 and c.shape[0] == 1:
                        c = c[0]                  # drop the batch=1 dim
                    if _is_time_leaf(path):
                        pad_n = a.shape[1] - c.shape[0]
                        c = jnp.pad(c, [(0, pad_n)] + [(0, 0)] * (c.ndim - 1))
                    return a.at[row].set(c.astype(a.dtype))

                return x, jax.tree_util.tree_map_with_path(write, arena, cache)

            # the donated arena is updated in-place instead of copying all
            # rows per dispatch (backends without donation support fall
            # back to a copy with a warning)
            self._jit_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._jit_cache[key]

    def _fn_decode(self, i: int):
        key = ("decode", i)
        if key not in self._jit_cache:
            kind, window = self._kind_window(i)

            def fn(bp, x, cache, pos):
                self._note_trace()
                return self.model.apply_block_decode(
                    bp, x, cache, pos, kind, window=window)

            self._jit_cache[key] = jax.jit(fn)
        return self._jit_cache[key]

    def _fn_decode_arena(self, si: int):
        """Per-node decode against arena span ``si``: the flat layout makes
        a layer dispatch identical to the PR-1 per-layer arena dispatch —
        gather/scatter B rows at ``slots + k * n_slots`` on the donated
        span arena, no layer slice materialized."""
        key = ("decode_arena", si)
        if key not in self._jit_cache:
            kind, window, _, _ = self._spans[si]

            def fn(bp, arena, x, pos, slots, off):
                self._note_trace()
                return self.model.apply_block_decode(
                    bp, x, arena, pos, kind, window=window,
                    slots=slots + off)

            self._jit_cache[key] = jax.jit(fn, donate_argnums=(1,))
        return self._jit_cache[key]

    def _fn_head(self):
        if "head" not in self._jit_cache:
            def fn(params, x):
                self._note_trace()
                h = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
                logits = self.model.unembed(params, h)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)

            self._jit_cache["head"] = jax.jit(fn)
        return self._jit_cache["head"]

    # ------------------------------------------------------------------
    # Jitted run functions (fused dispatch)
    # ------------------------------------------------------------------
    def _sub_span(self, si: int, a: int, b: int, span_params, offs):
        """Span ``si``'s stacked params + flat-arena row offsets restricted
        to layers [a, b] (static slices, resolved at trace time)."""
        _, _, lo, hi = self._spans[si]
        sp, off = span_params[si], offs[si]
        if a == lo and b == hi:
            return sp, off
        sl = slice(a - lo, b - lo + 1)
        return jax.tree.map(lambda l: l[sl], sp), off[sl]

    def _fn_mega(self, lo: int, hi: int, with_head: bool,
                 ctx: Optional[int] = None):
        """One fused decode dispatch for layers [lo, hi] (+ folded head).

        ``lo == 0``: the input is the (Bp,) token vector — the decode-cycle
        entry embedding happens inside the dispatch. ``lo == -1``: bare
        head (input is the (Bp, d) activation). Each overlapped span is one
        ``lax.scan`` over its stacked params with the flat span arena
        threaded through the carry; the whole arena list is donated as one
        pytree and returned updated in place. ``ctx`` (static power-of-two
        context bucket covering every member's position) bounds attention
        gathers/scores to actual context instead of arena capacity —
        bit-identical, and the reason fused decode beats per-node dispatch
        by more than just Python overhead.
        """
        key = ("mega", lo, hi, with_head, ctx)
        if key not in self._jit_cache:

            def fn(params, span_params, arenas, entry, pos, slots, offs):
                self._note_trace()
                x = (self.model.embed(params, entry) if lo == 0 else entry)
                new_arenas = list(arenas)
                if lo >= 0:
                    for si, (kind, window, slo, shi) in enumerate(self._spans):
                        a, b = max(lo, slo), min(hi, shi)
                        if a > b:
                            continue
                        sub_bp, sub_off = self._sub_span(
                            si, a, b, span_params, offs)
                        x, new_arenas[si] = self.model.apply_span_decode(
                            sub_bp, x, new_arenas[si], pos, kind,
                            offs=sub_off, window=window, slots=slots,
                            ctx=ctx)
                if with_head:
                    h = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
                    out = jnp.argmax(self.model.unembed(params, h),
                                     axis=-1).astype(jnp.int32)
                else:
                    out = x
                return out, new_arenas

            self._jit_cache[key] = jax.jit(fn, donate_argnums=(2,))
        return self._jit_cache[key]

    def _fn_prefill_run(self, lo: int, hi: int, embed: bool):
        """One fused prefill dispatch for layers [lo, hi] over a (B, S)
        token bucket (``embed=True``) or a (B, S, d) activation batch.
        Every member's layer-k cache rows are written into its arena rows
        (``slots + k * n_slots``) inside the scan body (padding rows carry
        the OOB sentinel slot — their writes drop)."""
        key = ("prefill_run", lo, hi, embed)
        if key not in self._jit_cache:

            def fn(params, span_params, arenas, entry, slots, offs):
                self._note_trace()
                x = self.model.embed(params, entry) if embed else entry
                positions = jnp.arange(x.shape[1])[None, :]

                def write(arena, cache, off):
                    row_idx = slots + off

                    def w(path, a, c):
                        if _is_time_leaf(path):
                            pad_n = a.shape[1] - c.shape[1]
                            c = jnp.pad(c, [(0, 0), (0, pad_n)]
                                        + [(0, 0)] * (c.ndim - 2))
                        return a.at[row_idx].set(c.astype(a.dtype),
                                                 mode="drop")
                    return jax.tree_util.tree_map_with_path(w, arena, cache)

                new_arenas = list(arenas)
                for si, (kind, window, slo, shi) in enumerate(self._spans):
                    a, b = max(lo, slo), min(hi, shi)
                    if a > b:
                        continue
                    sub_bp, sub_off = self._sub_span(
                        si, a, b, span_params, offs)
                    x, new_arenas[si] = self.model.apply_span_prefill(
                        sub_bp, new_arenas[si], x, kind, offs=sub_off,
                        window=window, positions=positions, write=write)
                return x, new_arenas

            self._jit_cache[key] = jax.jit(fn, donate_argnums=(2,))
        return self._jit_cache[key]

    # ------------------------------------------------------------------
    # Fused run execution
    # ------------------------------------------------------------------
    def _chunk_run(self, wl, node_ids):
        """Split a committed run into fusable phase chunks:
        ("prefill", [(phase, layer), ...]) or ("decode", lo, hi, with_head)
        — a bare head is ("decode", -1, -1, True). Memoized per node-id
        tuple (decode cycles repeat the same run every token); the cache
        value pins the workload object so its id() cannot be recycled by
        a different workload while the entry lives."""
        ck = (id(wl), tuple(node_ids))
        cached = self._chunk_cache.get(ck)
        if cached is not None:
            return cached[1]
        metas = [self._node_meta(wl, nid) for nid in node_ids]
        chunks = []
        i = 0
        while i < len(metas):
            ph, layer = metas[i]
            if ph in ("emb", "prefill"):
                j = i
                while j < len(metas) and metas[j][0] in ("emb", "prefill"):
                    j += 1
                chunks.append(("prefill", metas[i:j]))
                i = j
            elif ph == "decode":
                lo = hi = layer
                j = i + 1
                while (j < len(metas) and metas[j][0] == "decode"
                       and metas[j][1] == hi + 1):
                    hi += 1
                    j += 1
                with_head = j < len(metas) and metas[j][0] == "head"
                if with_head:
                    j += 1
                chunks.append(("decode", lo, hi, with_head))
                i = j
            else:                                 # bare head
                chunks.append(("decode", -1, -1, True))
                i += 1
        self._chunk_cache[ck] = (wl, chunks)
        return chunks

    def _prefill_groups(self, reqs, sts):
        """Group sub-batch members for batched prefill.

        Attention-family stacks (dense/MLA) bucket by power-of-two padded
        prompt length (and pad the group's batch to a power of two):
        bounded recompiles, one dispatch per bucket. Other stacks (MoE
        routing, SSM/recurrent state scans don't tolerate tail padding)
        prefill per-request at exact length — still one fused dispatch per
        request instead of one per layer.
        """
        bucketable = set(self.kinds) <= {"dense", "mla"}
        groups: Dict[tuple, list] = {}
        for r, st in zip(reqs, sts):
            if bucketable:
                key = (min(_pow2(st.prefill_len), self.max_len),)
            else:
                key = (st.prefill_len, r.rid)
            groups.setdefault(key, []).append((r, st))
        return [(members, key[0]) for key, members in groups.items()]

    def _run_prefill_chunk(self, reqs, sts, metas):
        has_emb = metas[0][0] == "emb"
        layers = [l for ph, l in metas if ph == "prefill"]
        last = bool(layers) and layers[-1] == len(self.kinds) - 1
        if has_emb and not layers:
            for st in sts:                        # bare emb node
                st.x = self.model.embed(self.params,
                                        st.prompt[None, :st.prefill_len])
            return
        if has_emb:
            fn = self._fn_prefill_run(0, layers[-1], embed=True)
            for members, Lb in self._prefill_groups(reqs, sts):
                Bg = len(members)
                Bp = _pow2(Bg)
                toks = np.zeros((Bp, Lb), np.int32)
                slots = np.full((Bp,), _PAD_SLOT, np.int32)
                for bi, (r, st) in enumerate(members):
                    toks[bi, :st.prefill_len] = st.prompt_np[:st.prefill_len]
                    slots[bi] = self.slot_of(r)   # may grow the arena first
                x, self.arenas = fn(self.params, self._span_params,
                                    self.arenas, jnp.asarray(toks),
                                    jnp.asarray(slots), self._offs())
                for bi, (r, st) in enumerate(members):
                    st.x = (None if last
                            else x[bi:bi + 1, :st.prefill_len])
        else:
            # resumed mid-prefill (st.x in flight): per-request fused span
            fn = self._fn_prefill_run(layers[0], layers[-1], embed=False)
            for r, st in zip(reqs, sts):
                slots = jnp.asarray([self.slot_of(r)], jnp.int32)
                st.x, self.arenas = fn(self.params, self._span_params,
                                       self.arenas, st.x, slots,
                                       self._offs())
                if last:
                    st.x = None

    def execute_run(self, model, sb: SubBatch, node_ids: Sequence[str]):
        """Execute a committed run; returns ``(latency, None)`` — per-node
        latency is unobservable inside fused dispatches, by design."""
        if self.cache_mode != "arena" or not self.fused or len(node_ids) == 1:
            s0 = self._san_host_syncs
            out = super().execute_run(model, sb, node_ids)
            self._san_max_syncs_per_run = max(
                self._san_max_syncs_per_run, self._san_host_syncs - s0)
            return out
        t0 = time.perf_counter()
        reqs = sb.live_requests
        wl = reqs[0].workload
        sts = [self.states[r.rid] for r in reqs]
        rids = tuple(r.rid for r in reqs)
        if self._xbatch is not None and self._xbatch[0] != rids:
            # another sub-batch is parked mid-cycle: its activations live
            # only in the batched cache — flush rows to per-request state
            # before this run's epilogue clobbers it
            self._flush_xbatch()
        B = len(reqs)
        Bp = _pow2(B)
        pos0 = None
        slots = None
        toks_dev = None                           # device (Bp,) sampled toks
        x_dev = None                              # device (Bp, d) mid-cycle x
        head_toks: List[jax.Array] = []
        n_heads = 0
        chunks = self._chunk_run(wl, node_ids)
        # one static context bucket covers every decode chunk of the run.
        # A chunk preceded by h heads reads rows <= pos0 + h, so the
        # deepest read index is pos0 + n_heads - 1 when the run ends on a
        # head, and pos0 + n_heads when a trailing headless decode chunk
        # continues past the run's last head — ctx must exceed it
        n_cycles = sum(1 for ch in chunks if ch[0] == "decode" and ch[3])
        ctx = None
        if any(ch[0] == "decode" for ch in chunks):
            trailing = chunks[-1][0] == "decode" and not chunks[-1][3]
            deepest = (max(st.pos for st in sts) + n_cycles
                       + (1 if trailing else 0))
            ctx = min(_pow2(deepest), self.max_len)
        bkey = (rids, Bp)
        for ch in chunks:
            if ch[0] == "prefill":
                self._run_prefill_chunk(reqs, sts, ch[1])
                continue
            _, lo, hi, with_head = ch
            if slots is None:
                slots = self._batched_slots(reqs, rids, padded_to=Bp)
                if self._posbatch is not None and self._posbatch[0] == bkey:
                    pos0 = self._posbatch[1]      # device-carried positions
                else:
                    pos0 = jnp.asarray([st.pos for st in sts]
                                       + [0] * (Bp - B), jnp.int32)
            pos = pos0 if n_heads == 0 else pos0 + n_heads
            if lo == 0:
                if toks_dev is None and self._tokbatch is not None \
                        and self._tokbatch[0] == bkey:
                    toks_dev = self._tokbatch[1]  # device-carried tokens
                entry = (toks_dev if toks_dev is not None else
                         jnp.asarray([st.next_token for st in sts]
                                     + [0] * (Bp - B), jnp.int32))
            else:
                entry = x_dev if x_dev is not None \
                    else self._entry_x(reqs, sts, B, Bp)
            fn = self._fn_mega(lo, hi, with_head, ctx)
            out, self.arenas = fn(self.params, self._span_params,
                                  self.arenas, entry, pos, slots,
                                  self._offs())
            if with_head:
                head_toks.append(out)
                toks_dev = out
                x_dev = None
                n_heads += 1
            else:
                x_dev = out
        # ---- run boundary: the ONLY sync point -----------------------
        if head_toks:
            # reprolint: disable=sync-point
            for arr in [np.asarray(t) for t in head_toks]:
                for bi, st in enumerate(sts):
                    st.next_token = int(arr[bi])  # reprolint: disable=sync-point
                    st.generated.append(st.next_token)
                    st.pos += 1
        if n_heads and pos0 is not None:
            self._posbatch = (bkey, pos0 + n_heads)
            self._tokbatch = (bkey, toks_dev)
        if x_dev is not None:
            self._xbatch = (rids, x_dev[:B])      # run ended mid-cycle
        else:
            self._xbatch = None
        jax.block_until_ready(self.arenas)  # reprolint: disable=sync-point
        # the whole epilogue (token readback + arena fence at ONE run
        # boundary) is a single logical sync event — the PR 2 contract
        self._san_host_syncs += 1
        self._san_max_syncs_per_run = max(self._san_max_syncs_per_run, 1)
        self.nodes_executed += len(node_ids)
        self.runs_executed += 1
        n = len(node_ids)
        self._release_slots([r for r in reqs
                             if r.idx + n >= len(r.sequence)])  # final node
        return time.perf_counter() - t0, None

    def _entry_x(self, reqs, sts, B, Bp):
        rids, x = self._batched_x(reqs, sts)
        self._xbatch = (rids, x)
        if Bp > B:
            x = jnp.pad(x, [(0, Bp - B), (0, 0)])
        return x

    # ------------------------------------------------------------------
    # Single-node dispatch (degenerate run; bit-exactness reference)
    # ------------------------------------------------------------------
    def execute(self, model, sb: SubBatch, node_id: str) -> float:
        t0 = time.perf_counter()
        reqs = sb.live_requests
        outs = []
        phase, i = self._node_meta(reqs[0].workload, node_id)
        if phase == "emb":
            for r in reqs:
                st = self.state(r)
                st.x = self.model.embed(
                    self.params, st.prompt[None, :st.prefill_len])
                outs.append(st.x)
        elif phase == "prefill":
            bp = self._layer_params(i)
            last = (i == len(self.kinds) - 1)
            if self.cache_mode == "arena":
                si, k = self._layer_loc[i]
                fn = self._fn_prefill_arena(si)
                for r in reqs:
                    st = self.state(r)
                    slot = self.slot_of(r)    # may grow the arena: resolve
                    st.x, self.arenas[si] = fn(bp, self.arenas[si], st.x,
                                               slot + k * self.n_slots)
                    outs.append(st.x)
                    if last:                      # prefill done
                        st.x = None
            else:
                fn = self._fn_prefill(i)
                for r in reqs:
                    st = self.state(r)
                    st.x, cache = fn(bp, st.x)
                    st.caches[i] = self._pad_cache(cache, st.prefill_len)
                    outs.append(st.x)
                    if last:
                        st.x = None
        elif phase == "decode":
            bp = self._layer_params(i)
            sts = [self.state(r) for r in reqs]
            fresh = None
            if i == 0:
                toks = jnp.asarray([st.next_token for st in sts], jnp.int32)
                fresh = self.model.embed(self.params, toks)   # (B, d)
            pos = jnp.asarray([st.pos for st in sts], jnp.int32)
            if self.cache_mode == "arena":
                rids, x = self._batched_x(reqs, sts, fresh)
                si, k = self._layer_loc[i]
                fn = self._fn_decode_arena(si)
                slots = self._batched_slots(reqs, rids)
                x, self.arenas[si] = fn(bp, self.arenas[si], x, pos, slots,
                                        k * self.n_slots)
                self._xbatch = (rids, x)
            else:
                if fresh is not None:
                    for bi, st in enumerate(sts):
                        st.x = fresh[bi]
                x = jnp.stack([st.x for st in sts])           # (B, d)
                fn = self._fn_decode(i)
                cache = jax.tree.map(lambda *ls: jnp.stack(ls),
                                     *[st.caches[i] for st in sts])
                x, new_cache = fn(bp, x, cache, pos)
                for bi, st in enumerate(sts):
                    st.caches[i] = jax.tree.map(lambda l: l[bi], new_cache)
                    st.x = x[bi]
            outs.append(x)
        elif phase == "head":
            fn = self._fn_head()
            sts = [self.state(r) for r in reqs]
            if self.cache_mode == "arena":
                rids, x = self._batched_x(reqs, sts)
                self._xbatch = (rids, x)
            else:
                x = jnp.stack([st.x for st in sts])
            toks = fn(self.params, x)
            outs.append(toks)
            toks = np.asarray(toks)
            for bi, st in enumerate(sts):
                st.next_token = int(toks[bi])
                st.generated.append(st.next_token)
                st.pos += 1
            # single-node head advanced host state: the device-carried
            # run vectors are stale now
            self._posbatch = self._tokbatch = None
        else:
            raise KeyError(f"unknown node {node_id!r}")
        self.nodes_executed += 1
        # per-node dispatch fences every node — one sync event per NODE,
        # which is exactly why fused runs beat it (their whole run is one)
        self._san_host_syncs += 1
        for o in outs:
            jax.block_until_ready(o)
        # free arena slots of requests that just executed their final node
        # (on_finished() releases them too — both are idempotent — but this
        # covers direct engine driving without the server loop)
        self._release_slots([r for r in reqs
                             if r.idx == len(r.sequence) - 1])
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def _pad_cache(self, cache, prefill_len: int):
        """Legacy mode: prefill returns time-axis caches sized to the
        prompt; pad them to ``max_len`` so merged decode batches share one
        cache shape. Only leaves named in ``_TIME_AXIS_KEYS`` (k/v/ckv/
        krope) have a time axis; recurrent state/conv leaves pass through
        untouched."""

        def pad(path, leaf):
            if not _is_time_leaf(path):
                return leaf
            if leaf.ndim >= 2 and leaf.shape[0] == 1:
                leaf = leaf[0]                    # drop the batch=1 dim
            pad_n = self.max_len - leaf.shape[0]
            if pad_n < 0:
                raise ValueError(
                    f"cache leaf time-dim {leaf.shape} exceeds engine "
                    f"max_len {self.max_len}")
            return jnp.pad(leaf, [(0, pad_n)] + [(0, 0)] * (leaf.ndim - 1))

        padded = jax.tree_util.tree_map_with_path(pad, cache)
        # non-time leaves still carry the batch=1 dim — drop it
        return jax.tree_util.tree_map_with_path(
            lambda p, l: (l[0] if not _is_time_leaf(p) and l.ndim >= 1
                          and l.shape[0] == 1 else l),
            padded)
