"""The one model-keyed Backend contract every execution substrate implements.

A *backend* is what a :class:`~repro.serving.session.ServingSession` (and
therefore the ``InferenceServer`` wrapper) drives: something that can
execute committed node runs for a sub-batch of a named **model** and
report latency on its own clock —

  * ``SimExecutor`` (``server.py``) — the analytical NPU latency model;
    latency is *virtual* time (the paper's methodology). It reads each
    request's own workload, so ONE instance serves every registered model,
  * ``JaxEngine`` (``engine.py``) — real jitted dispatches on a reduced
    model; latency is *wall-clock* time measured at run boundaries. One
    engine holds one model's parameters and KV arena, so multi-tenant
    sessions put one engine per model behind a :class:`MultiBackend`.

Every method takes the registry model name first (``prepare(model, req,
...)``, ``execute_run(model, sb, run)``): the session always says *which*
model's work this is, single-model backends are free to ignore the key,
and :class:`MultiBackend` routes on it. The session never branches on
which backend it holds: admission, clock advancement, handle lifecycle,
and metrics are identical — only the meaning of a second differs. All
backends behind one session share one **device-time clock**: whichever
backend executes a run, its latency advances the same ``session.now``, so
co-located models contend for device time exactly as on one accelerator.

Beyond execution, the contract covers the two things an online front-end
needs that the offline trace loop did not:

  * ``prepare(model, req, rng, prompt_tokens=...)`` — per-request setup at
    submit time (the JAX engine registers/samples the prompt here; the
    simulator needs nothing),
  * ``token_count(model, req)`` / ``tokens(model, req)`` — response-
    progress observability at run boundaries, driving TTFT/TPOT metrics
    and the ``on_token`` streaming callbacks. The base implementation
    derives a *virtual* token count from request progress (one token per
    completed decode cycle; a static graph's single response counts as one
    token on completion), which is exactly right for the simulator; the
    JAX engine overrides both with its actually sampled token ids.

``Executor`` — the pre-session name of this contract — is retired;
accessing ``repro.serving.server.Executor`` still resolves to ``Backend``
behind a ``DeprecationWarning``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.request import Request, SubBatch


class BackendError(RuntimeError):
    """A backend dispatch fault with *defined* session semantics.

    Raised by ``execute``/``execute_run`` when a dispatch cannot complete.
    The session's failure model (see ``ServingSession``) treats it as a
    whole-run loss: every member's device-side progress (KV rows, slot)
    is discarded via :meth:`Backend.reset_request` and — when
    ``retryable`` — the requests are requeued with capped exponential
    backoff to replay prefill from node 0; retries exhausted (or
    ``retryable=False``) turns them terminal ``FAILED``, an SLA
    violation. ``latency`` is the device time burned before the fault
    was detected — charged to the session clock so faults are not free.

    Subclasses ``RuntimeError`` deliberately: code predating the failure
    model that catches RuntimeError keeps working unchanged.
    """

    def __init__(self, message: str, *, latency: float = 0.0,
                 retryable: bool = True):
        super().__init__(message)
        self.latency = float(latency)
        self.retryable = retryable


class TransientBackendError(BackendError):
    """A fault expected to clear on retry (flaky dispatch, preempted
    device, dropped interconnect message)."""


class BackendOOMError(BackendError):
    """Slot-allocation failure under memory pressure: the KV arena is at
    its cap with every slot held. Retryable — residency drains as live
    requests complete, so a backed-off replay can succeed."""


@dataclass
class MemoryStats:
    """One backend memory pool's accounting snapshot.

    A *slot* is the unit of KV-cache residency (one concurrently served
    request). ``slots_total`` is the pool's CURRENT capacity (a paged
    arena grows and shrinks it), ``max_slots`` the configured hard cap
    (``None`` = unbounded — memory-aware admission disengages). ``pool``
    identifies the owning device pool (``id()`` of the arena holder):
    models whose stats report the same pool contend for the same slots,
    which is how the session tells one shared simulated device apart from
    per-model engines with disjoint arenas behind a ``MultiBackend``.

    When queried for a specific model (``memory_stats(model=...)``),
    ``slots_live``/``bytes_resident_model`` are that model's share while
    the capacity fields stay pool-wide.
    """
    slots_total: int = 0
    slots_live: int = 0
    slots_free: int = 0
    bytes_resident: int = 0          # pool-wide resident KV bytes
    bytes_per_slot: float = 0.0
    max_slots: Optional[int] = None  # None = unbounded (no admission cap)
    pool: int = 0                    # identity of the owning device pool

    @property
    def bounded(self) -> bool:
        return self.max_slots is not None


@dataclass
class SanitizerStats:
    """Runtime hot-path sanitizer counters (the dynamic half of reprolint).

    The static checkers (``repro.analysis``) prove the *code* contains no
    stray sync or retrace constructs; these counters prove the *execution*
    honored the contract: ``host_syncs`` counts run-boundary host
    synchronization events (one per committed run epilogue — readback of
    the head tokens plus the arena fence count as ONE logical sync, since
    they happen at one boundary), ``retraces`` counts actual jit traces
    (a Python-side effect inside each jitted body runs only while JAX is
    tracing, so this is exact — warmup compiles show up here, and a
    steady-state phase must add zero). ``runs`` mirrors the engine's
    committed-run counter so callers can assert ``syncs_delta <=
    runs_delta`` over any window. Backends with no device state report
    all-zero stats (the simulator never syncs or traces anything).
    """
    runs: int = 0
    host_syncs: int = 0          # run-boundary sync events (<= runs)
    retraces: int = 0            # jit traces = XLA compiles triggered
    max_syncs_per_run: int = 0   # worst single run (contract: <= 1)

    @property
    def ok(self) -> bool:
        return self.max_syncs_per_run <= 1


class Backend:
    def prepare(self, model: str, req: Request, rng,
                prompt_tokens=None) -> None:
        """Per-request setup at submission time (before the request can be
        scheduled). Real engines allocate/register request state here —
        e.g. the JAX engine stores the prompt (``prompt_tokens``, or a
        random one sampled from ``rng`` at the request's ``prompt_len``).
        The analytic simulator keeps no per-request state — default no-op."""

    def execute(self, model: str, sb: SubBatch, node_id: str) -> float:
        """Execute one node for a sub-batch; returns latency in seconds."""
        raise NotImplementedError

    def execute_run(self, model: str, sb: SubBatch,
                    node_ids: Sequence[str]) -> Tuple[float, Optional[List[float]]]:
        """Execute a committed run of consecutive nodes for one sub-batch.

        Returns ``(total_latency, per_node_latencies)``. Backends that
        fuse the run into fewer device dispatches than nodes return
        ``(total, None)`` — per-node latency is unobservable inside a fused
        dispatch, and the server clock only needs run latency (sync points
        live at scheduler-visible run boundaries). The default loops
        :meth:`execute` per node, the degenerate single-dispatch-per-node
        behavior.
        """
        lats = [self.execute(model, sb, nid) for nid in node_ids]
        return sum(lats), lats

    def on_finished(self, model: str, reqs: Sequence[Request]) -> None:
        """Completion hook: the session calls this with every request that
        finished at the last run boundary, so stateful backends can
        release per-request *device* resources (e.g. KV-cache arena
        slots). Host-side results (generated tokens) must survive it —
        they stay readable until :meth:`release_request`. The analytic
        simulator keeps no per-request state — default no-op."""

    def reset_request(self, model: str, req: Request) -> None:
        """Discard ``req``'s *device-side* progress after a fault so the
        request can re-execute from node 0 (prefill replay): release its
        KV slot back to the pool idempotently and reset any per-request
        execution state to its freshly-prepared form — the prompt (and
        host-side tokens already streamed) must survive, a retry
        regenerates the rest bit-exactly. Stateless backends need
        nothing — default no-op."""

    def release_request(self, model: str, req: Request) -> None:
        """Forget ``req`` entirely (``ServingSession.release``): drop any
        remaining host-side state, e.g. the JAX engine's per-request
        prompt/token record. Long-lived online sessions call this per
        completed request; offline trace replays never do, so results
        remain inspectable after a drained run. Default no-op."""

    def token_count(self, model: str, req: Request) -> int:
        """Response tokens produced so far for ``req`` (consulted at run
        boundaries). Default: derived from request progress — one token
        per completed decode cycle, or one token at completion for static
        (single-response) graphs."""
        return req.n_tokens

    def tokens(self, model: str, req: Request) -> Optional[Sequence[int]]:
        """Actual sampled token ids for ``req`` (prefix of length
        :meth:`token_count`), or ``None`` when the backend has no real
        tokens (the simulator) — streaming then reports placeholder ids."""
        return None

    def memory_stats(self, model: Optional[str] = None) -> MemoryStats:
        """Device-memory accounting for this backend's KV pool (pool-wide,
        or one model's share when ``model`` is given). The default is an
        empty, unbounded pool — backends with no device state (or no
        accounting) never constrain memory-aware admission."""
        return MemoryStats(pool=id(self))

    def sanitizer_stats(self, model: Optional[str] = None) -> SanitizerStats:
        """Hot-path sanitizer counters (sync/retrace accounting). The
        default is all-zero: a backend with no device dispatches never
        syncs or retraces, which trivially satisfies the contract."""
        return SanitizerStats()


class MultiBackend(Backend):
    """Model-keyed mux over per-model backends.

    ``MultiBackend({"llama": JaxEngine(cfg_a), "mamba": JaxEngine(cfg_b)})``
    routes every contract call to the named model's backend, passing the
    model key through (inner backends may themselves be shared across
    keys — e.g. one stateless ``SimExecutor`` registered under several
    names). The mux is what makes per-model engines look like ONE device
    to the session: all inner latencies accumulate on the session's single
    device-time clock (each model's share of it is tracked by the session
    in ``ServerLog.busy_by_model``).
    """

    def __init__(self, backends: Dict[str, Backend]):
        if not backends:
            raise ValueError("MultiBackend needs at least one backend")
        self.backends = dict(backends)

    def backend_for(self, model: str) -> Backend:
        try:
            return self.backends[model]
        except KeyError:
            raise KeyError(
                f"no backend for model {model!r} "
                f"(have: {sorted(self.backends)})") from None

    # ------------------------------------------------------------------
    def prepare(self, model, req, rng, prompt_tokens=None):
        self.backend_for(model).prepare(model, req, rng,
                                        prompt_tokens=prompt_tokens)

    def execute(self, model, sb, node_id):
        return self.backend_for(model).execute(model, sb, node_id)

    def execute_run(self, model, sb, node_ids):
        return self.backend_for(model).execute_run(model, sb, node_ids)

    def on_finished(self, model, reqs):
        self.backend_for(model).on_finished(model, reqs)

    def reset_request(self, model, req):
        self.backend_for(model).reset_request(model, req)

    def release_request(self, model, req):
        self.backend_for(model).release_request(model, req)

    def token_count(self, model, req):
        return self.backend_for(model).token_count(model, req)

    def tokens(self, model, req):
        return self.backend_for(model).tokens(model, req)

    def memory_stats(self, model=None):
        """Route to the named model's backend; with no model, aggregate
        across the DISTINCT inner backends (shared instances counted
        once). The aggregate is a reporting view — admission gating
        always queries per model, where the ``pool`` id is meaningful."""
        if model is not None:
            return self.backend_for(model).memory_stats(model)
        seen: Dict[int, MemoryStats] = {}
        for name, be in self.backends.items():
            if id(be) not in seen:
                seen[id(be)] = be.memory_stats()
        agg = MemoryStats(pool=id(self))
        caps: List[Optional[int]] = []
        for st in seen.values():
            agg.slots_total += st.slots_total
            agg.slots_live += st.slots_live
            agg.slots_free += st.slots_free
            agg.bytes_resident += st.bytes_resident
            caps.append(st.max_slots)
        if caps and all(c is not None for c in caps):
            agg.max_slots = sum(caps)
        if agg.slots_total:
            agg.bytes_per_slot = agg.bytes_resident / agg.slots_total
        return agg

    def sanitizer_stats(self, model=None):
        """Route to the named model's backend; with no model, sum the
        counters across DISTINCT inner backends (shared instances counted
        once) — ``max_syncs_per_run`` takes the worst inner value, so the
        aggregate ``ok`` property holds iff every engine's does."""
        if model is not None:
            return self.backend_for(model).sanitizer_stats(model)
        seen: Dict[int, SanitizerStats] = {}
        for be in self.backends.values():
            if id(be) not in seen:
                seen[id(be)] = be.sanitizer_stats()
        agg = SanitizerStats()
        for st in seen.values():
            agg.runs += st.runs
            agg.host_syncs += st.host_syncs
            agg.retraces += st.retraces
            agg.max_syncs_per_run = max(agg.max_syncs_per_run,
                                        st.max_syncs_per_run)
        return agg


@dataclass
class NodeLat:
    """Per-node-id (or per-fused-run-span) latency accumulator."""
    count: int = 0
    total: float = 0.0

    @property
    def mean(self) -> float:
        return self.total / max(1, self.count)


@dataclass
class ServerLog:
    nodes_executed: int = 0
    runs_executed: int = 0
    busy_time: float = 0.0
    batch_size_sum: int = 0
    # backend faults the session absorbed (BackendError from execute_run:
    # injected or real); the faulted dispatch's detection latency is in
    # busy_time but its nodes are NOT in nodes_executed — nothing ran
    faults: int = 0
    # per-node-id latency breakdown; fused runs (no per-node observability)
    # are keyed by their span, e.g. "D0..head" — making run-fusion wins
    # visible per phase next to the per-node entries. Multi-model sessions
    # prefix keys with the model name ("llama:D0..head").
    node_lat: Dict[str, NodeLat] = field(default_factory=dict)
    # per-model share of the (single) device-time clock
    busy_by_model: Dict[str, float] = field(default_factory=dict)

    def record(self, key: str, latency: float, n: int = 1):
        ent = self.node_lat.setdefault(key, NodeLat())
        ent.count += n
        ent.total += latency

    @property
    def avg_batch_size(self) -> float:
        return self.batch_size_sum / max(1, self.nodes_executed)

    @property
    def avg_run_length(self) -> float:
        return self.nodes_executed / max(1, self.runs_executed)


def run_label(node_ids: Sequence[str]) -> str:
    return (node_ids[0] if len(node_ids) == 1
            else f"{node_ids[0]}..{node_ids[-1]}")
