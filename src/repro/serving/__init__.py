from .workload import (Workload, NodeDesc, Segment, LengthDist,
                       wmt_like_length_dist, fixed_length, get_workload,
                       from_model_config, PAPER_WORKLOADS)
from .npu_model import NPUPerfModel, HardwareSpec, PAPER_NPU, TPU_V5E
from .traffic import (Trace, poisson_trace, bursty_trace, colocated_trace,
                      with_sla_classes)
from .backend import Backend, ServerLog, run_label
from .session import ServingSession, RequestHandle, HandleState, run_trace
from .server import InferenceServer, SimExecutor, Executor, run_policy
from .metrics import ServeStats

__all__ = [
    "Workload", "NodeDesc", "Segment", "LengthDist", "wmt_like_length_dist",
    "fixed_length", "get_workload", "from_model_config", "PAPER_WORKLOADS",
    "NPUPerfModel", "HardwareSpec", "PAPER_NPU", "TPU_V5E",
    "Trace", "poisson_trace", "bursty_trace", "colocated_trace",
    "with_sla_classes",
    "Backend", "ServerLog", "run_label",
    "ServingSession", "RequestHandle", "HandleState", "run_trace",
    "InferenceServer", "SimExecutor", "Executor", "run_policy", "ServeStats",
]
