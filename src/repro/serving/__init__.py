from .workload import (Workload, NodeDesc, Segment, LengthDist,
                       wmt_like_length_dist, fixed_length, get_workload,
                       from_model_config, PAPER_WORKLOADS)
from .npu_model import NPUPerfModel, HardwareSpec, PAPER_NPU, TPU_V5E
from .traffic import (Trace, poisson_trace, poisson_mixture, bursty_trace,
                      colocated_trace, with_sla_classes)
from .backend import (Backend, BackendError, BackendOOMError, MemoryStats,
                      MultiBackend, ServerLog, TransientBackendError,
                      run_label)
from .registry import ModelEntry, ModelRegistry
from .session import (ServingSession, RequestHandle, HandleState,
                      RetryPolicy, BrownoutConfig, run_trace,
                      run_mixture, DEFAULT_MODEL)
from .server import InferenceServer, SimExecutor, run_policy
from .metrics import ServeStats
from .faults import (FaultSpec, FaultInjectingBackend, parse_fault_spec,
                     parse_fault_specs)

__all__ = [
    "Workload", "NodeDesc", "Segment", "LengthDist", "wmt_like_length_dist",
    "fixed_length", "get_workload", "from_model_config", "PAPER_WORKLOADS",
    "NPUPerfModel", "HardwareSpec", "PAPER_NPU", "TPU_V5E",
    "Trace", "poisson_trace", "poisson_mixture", "bursty_trace",
    "colocated_trace", "with_sla_classes",
    "Backend", "BackendError", "BackendOOMError", "TransientBackendError",
    "MemoryStats", "MultiBackend", "ServerLog", "run_label",
    "ModelEntry", "ModelRegistry",
    "ServingSession", "RequestHandle", "HandleState", "RetryPolicy",
    "BrownoutConfig", "run_trace", "run_mixture", "DEFAULT_MODEL",
    "InferenceServer", "SimExecutor", "run_policy", "ServeStats",
    "FaultSpec", "FaultInjectingBackend", "parse_fault_spec",
    "parse_fault_specs",
]


def __getattr__(name):
    if name == "Executor":                  # retired alias of Backend
        import warnings
        warnings.warn("Executor is deprecated; use repro.serving.Backend",
                      DeprecationWarning, stacklevel=2)
        return Backend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
