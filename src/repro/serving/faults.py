"""Seeded fault injection: a deterministic chaos wrapper for backends.

Real serving fleets lose dispatches — preempted device VMs, XLA OOMs
under fragmentation, straggler replicas — and an SLA-aware scheduler is
only credible if its attainment numbers survive them. The
:class:`FaultInjectingBackend` makes those failures *reproducible*: it
wraps any model-keyed :class:`~repro.serving.backend.Backend`
(``SimExecutor``, ``JaxEngine``, a ``MultiBackend`` mux) and, on each
``execute_run`` dispatch, draws ONE uniform from a per-model seeded
stream to decide among

  * **transient failure** — raises
    :class:`~repro.serving.backend.TransientBackendError` (retryable;
    the session's RetryPolicy requeues the members with backoff),
  * **injected OOM** — raises
    :class:`~repro.serving.backend.BackendOOMError` (a transient
    slot-allocation failure, also retryable),
  * **latency-spike straggler** — the run executes *correctly* but its
    reported latency (total and per-node) is multiplied by
    ``straggler_factor``: results are bit-exact, deadlines burn,
  * **normal dispatch** — delegated untouched.

Determinism: each model's stream is ``default_rng([seed, crc32(model)])``
— independent of every other model, of the session's prompt-sampling
stream, and of dict ordering; two runs with the same seed, trace, and
spec inject byte-identical fault sequences. Exactly one draw happens per
``execute_run`` whether or not any probability is nonzero, so enabling a
zero-rate spec never perturbs the sequence of a nonzero one.

Per-model specs: pass ``{model_name: FaultSpec}`` to fault only some
tenants (e.g. chaos on the bulk tier while the interactive tier stays
clean); a single :class:`FaultSpec` applies to every model.

The single-node ``execute`` path (legacy pre-run-commit servers) is
delegated without injection — the failure model is defined at run
granularity, matching the session's retry unit.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Union

import numpy as np

from .backend import (Backend, BackendOOMError, TransientBackendError)


@dataclass(frozen=True)
class FaultSpec:
    """Per-dispatch fault probabilities (disjoint bands of one uniform
    draw — their sum must not exceed 1).

    ``fault_latency`` is the device time a failed dispatch burns before
    the failure is detected (charged to the session clock via
    ``BackendError.latency`` — faults are not free retries).
    ``straggler_factor`` multiplies a straggler run's reported latency."""
    p_transient: float = 0.0
    p_oom: float = 0.0
    p_straggler: float = 0.0
    straggler_factor: float = 4.0
    fault_latency: float = 0.0

    def __post_init__(self):
        probs = (self.p_transient, self.p_oom, self.p_straggler)
        if any(p < 0.0 for p in probs) or sum(probs) > 1.0 + 1e-12:
            raise ValueError(
                f"fault probabilities must be non-negative and sum to "
                f"<= 1: {self}")
        if self.straggler_factor < 1.0 or self.fault_latency < 0.0:
            raise ValueError(
                f"straggler_factor must be >= 1 and fault_latency >= 0: "
                f"{self}")

    @property
    def any_faults(self) -> bool:
        return (self.p_transient > 0 or self.p_oom > 0
                or self.p_straggler > 0)


def parse_fault_spec(text: str) -> FaultSpec:
    """Parse a CLI fault spec: comma-separated ``kind:value`` fields —

        ``transient:0.05,oom:0.01,straggler:0.1x8,latency:0.002``

    ``straggler`` takes an optional ``xFACTOR`` suffix (latency
    multiplier, default 4). Unknown kinds raise."""
    kw = {}
    for fld in filter(None, (f.strip() for f in text.split(","))):
        kind, sep, val = fld.partition(":")
        if not sep:
            raise ValueError(f"malformed fault spec field {fld!r} "
                             f"(expected kind:value)")
        kind = kind.strip().lower()
        if kind == "transient":
            kw["p_transient"] = float(val)
        elif kind == "oom":
            kw["p_oom"] = float(val)
        elif kind == "straggler":
            p, x, factor = val.partition("x")
            kw["p_straggler"] = float(p)
            if x:
                kw["straggler_factor"] = float(factor)
        elif kind == "latency":
            kw["fault_latency"] = float(val)
        else:
            raise ValueError(
                f"unknown fault kind {kind!r} in spec {text!r} — expected "
                f"transient / oom / straggler / latency")
    return FaultSpec(**kw)


def parse_fault_specs(text: str) -> Union[FaultSpec,
                                          Dict[str, FaultSpec]]:
    """Parse a possibly model-keyed CLI spec: either one global spec or
    ``;``-separated ``model=spec`` entries, e.g.
    ``bulk=transient:0.1;gold=straggler:0.02x6``."""
    if "=" not in text:
        return parse_fault_spec(text)
    out: Dict[str, FaultSpec] = {}
    for part in filter(None, (p.strip() for p in text.split(";"))):
        model, sep, spec = part.partition("=")
        if not sep or not model.strip():
            raise ValueError(f"malformed per-model fault spec {part!r} "
                             f"(expected model=kind:value,...)")
        out[model.strip()] = parse_fault_spec(spec)
    return out


class FaultInjectingBackend(Backend):
    """Deterministic chaos wrapper around any model-keyed backend."""

    def __init__(self, inner: Backend,
                 spec: Union[FaultSpec, Dict[str, FaultSpec]],
                 *, seed: int = 0):
        self.inner = inner
        self._spec = spec
        self._seed = seed
        self._rngs: Dict[str, np.random.Generator] = {}
        # injected-fault counters per model (observability + tests)
        self.counts: Dict[str, Dict[str, int]] = {}

    def spec_for(self, model: str) -> Optional[FaultSpec]:
        if isinstance(self._spec, FaultSpec):
            return self._spec
        return self._spec.get(model)

    def _rng(self, model: str) -> np.random.Generator:
        rng = self._rngs.get(model)
        if rng is None:
            # crc32 keys the stream on the model NAME, so the sequence is
            # independent of registration order and of other models
            rng = np.random.default_rng(
                [self._seed, zlib.crc32(model.encode("utf-8"))])
            self._rngs[model] = rng
        return rng

    def _count(self, model: str, kind: str):
        per = self.counts.setdefault(
            model, {"draws": 0, "transient": 0, "oom": 0, "straggler": 0})
        per[kind] += 1

    def fault_stats(self) -> Dict[str, Dict[str, int]]:
        """Injected-fault counters: model -> {draws, transient, oom,
        straggler}."""
        return {m: dict(per) for m, per in self.counts.items()}

    # ------------------------------------------------------------------
    def execute_run(self, model, sb, node_ids):
        spec = self.spec_for(model)
        if spec is None or not spec.any_faults:
            return self.inner.execute_run(model, sb, node_ids)
        self._count(model, "draws")
        u = float(self._rng(model).random())
        if u < spec.p_transient:
            self._count(model, "transient")
            raise TransientBackendError(
                f"injected transient fault on {model!r} run "
                f"{node_ids[0]}..{node_ids[-1]} "
                f"(batch={sb.size}, u={u:.4f})",
                latency=spec.fault_latency)
        if u < spec.p_transient + spec.p_oom:
            self._count(model, "oom")
            raise BackendOOMError(
                f"injected slot-allocation OOM on {model!r} run "
                f"{node_ids[0]}..{node_ids[-1]} "
                f"(batch={sb.size}, u={u:.4f})",
                latency=spec.fault_latency)
        latency, per_node = self.inner.execute_run(model, sb, node_ids)
        if u > 1.0 - spec.p_straggler:
            # straggler: correct results, inflated device time
            self._count(model, "straggler")
            f = spec.straggler_factor
            latency = latency * f
            if per_node is not None:
                per_node = [l * f for l in per_node]
        return latency, per_node

    # -- pure delegation: the wrapper is transparent everywhere else ----
    def prepare(self, model, req, rng, prompt_tokens=None):
        return self.inner.prepare(model, req, rng,
                                  prompt_tokens=prompt_tokens)

    def execute(self, model, sb, node_id):
        return self.inner.execute(model, sb, node_id)

    def on_finished(self, model, reqs):
        return self.inner.on_finished(model, reqs)

    def reset_request(self, model, req):
        return self.inner.reset_request(model, req)

    def release_request(self, model, req):
        return self.inner.release_request(model, req)

    def token_count(self, model, req):
        return self.inner.token_count(model, req)

    def tokens(self, model, req):
        return self.inner.tokens(model, req)

    def memory_stats(self, model=None):
        return self.inner.memory_stats(model)

    def sanitizer_stats(self, model=None):
        return self.inner.sanitizer_stats(model)
