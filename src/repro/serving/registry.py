"""The model registry: the front door for multi-tenant serving.

The paper evaluates LazyBatching on co-located DNNs sharing one NPU:
batching is per-model (batch tables are per-graph), while scheduling
arbitrates node-level work *across* the concurrently served graphs. The
:class:`ModelRegistry` is that co-location made explicit — each registered
model owns

  * a **name** (the routing key: ``submit(req, model=...)``, traffic
    tags, backend muxing, per-model stats),
  * a **workload** (its node graph / request template; optional for the
    legacy single-model sessions that infer it from submitted requests),
  * a **policy** — its own batching policy and therefore its own
    BatchTable and slack predictor; admission and merging never cross
    models.

What *is* shared is the device: one :class:`~repro.serving.backend.
Backend` (possibly a :class:`~repro.serving.backend.MultiBackend` mux)
executes every model's committed runs on one session clock, and one
cross-model :class:`~repro.core.arbiter.Arbiter` decides whose run
dispatches next.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core.policies import Policy


@dataclass
class ModelEntry:
    """One registered model: name + workload + its private policy.

    ``mem_share`` caps this model's admitted-resident KV slots at a
    fraction of the backend pool's ``max_slots`` under memory-aware
    admission (``None`` = uncapped; the session falls back to the
    arbiter's ``mem_shares``). Per-model shares are what keep a bulk
    tenant from starving an interactive tenant of device memory.

    ``shed_priority`` ranks the model for graceful load shedding (higher
    = more protected): under an ingress-queue overflow or an active
    brownout, work from strictly lower-priority models is shed first.
    Ties (the default: every model at 0) shed deadline-aware instead."""
    name: str
    workload: Optional[object]          # serving.workload.Workload
    policy: Policy
    index: int                          # registration order (arbiter RR)
    mem_share: Optional[float] = None   # fraction of the pool's max_slots
    shed_priority: int = 0              # higher = protected tier

    def __repr__(self):
        wl = getattr(self.workload, "name", None)
        share = f", mem_share={self.mem_share:g}" if self.mem_share else ""
        return (f"ModelEntry({self.name!r}, workload={wl!r}, "
                f"policy={self.policy.name}{share})")


class ModelRegistry:
    """Name-keyed registry of served models, in registration order."""

    def __init__(self):
        self._entries: Dict[str, ModelEntry] = {}

    def register(self, name: str, workload=None, *, policy: Policy,
                 mem_share: Optional[float] = None,
                 shed_priority: int = 0) -> ModelEntry:
        if name in self._entries:
            raise ValueError(f"model {name!r} already registered")
        if mem_share is not None and not 0.0 < mem_share <= 1.0:
            raise ValueError(
                f"mem_share for {name!r} must lie in (0, 1]: {mem_share}")
        entry = ModelEntry(name=name, workload=workload, policy=policy,
                           index=len(self._entries), mem_share=mem_share,
                           shed_priority=shed_priority)
        self._entries[name] = entry
        return entry

    # ------------------------------------------------------------------
    def __getitem__(self, name: str) -> ModelEntry:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"model {name!r} is not registered "
                f"(registered: {sorted(self._entries) or 'none'})") from None

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> List[ModelEntry]:
        """All entries in registration order (dicts preserve insertion)."""
        return list(self._entries.values())

    def names(self) -> List[str]:
        return list(self._entries)
