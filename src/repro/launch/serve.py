"""Serving launcher.

Two modes:
  * ``--engine sim``  — discrete-event simulation on the NPU latency model
    (any architecture/workload at any load, instantly),
  * ``--engine jax``  — the real node-level JAX engine on a reduced model
    (CPU-runnable end-to-end, generation-verified).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --policy lazyb --rate 200 --engine sim
"""
from __future__ import annotations

import argparse

import numpy as np

from ..configs import ARCHITECTURES, get_config
from ..core.policies import (CellularBatching, GraphBatching, LazyBatching,
                             Oracle, Serial)
from ..core.slack import OracleSlackPredictor, SlackPredictor
from ..serving.npu_model import NPUPerfModel, PAPER_NPU, TPU_V5E
from ..serving.server import InferenceServer, SimExecutor
from ..serving.traffic import Trace, bursty_trace, poisson_trace
from ..serving.workload import PAPER_WORKLOADS, get_workload


def build_policy(name: str, wl, perf, sla: float, max_batch: int,
                 window: float):
    if name == "serial":
        return Serial()
    if name == "graphb":
        return GraphBatching(window=window, max_batch=max_batch)
    if name == "cellular":
        return CellularBatching(max_batch=max_batch)
    if name == "lazyb":
        return LazyBatching(SlackPredictor.build([wl], perf, sla),
                            max_batch=max_batch)
    if name == "oracle":
        return Oracle(OracleSlackPredictor(sla, perf), max_batch=max_batch)
    raise KeyError(name)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="transformer",
                    help="paper workload or assigned architecture id")
    ap.add_argument("--policy", default="lazyb",
                    choices=["serial", "graphb", "cellular", "lazyb",
                             "oracle"])
    ap.add_argument("--engine", default="sim", choices=["sim", "jax"])
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--sla", type=float, default=0.1)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--window", type=float, default=0.025)
    ap.add_argument("--bursty", action="store_true",
                    help="MMPP bursty arrivals instead of Poisson")
    ap.add_argument("--hw", default="paper", choices=["paper", "v5e"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.engine == "jax":
        # delegate to the verified end-to-end driver
        import runpy
        import sys
        sys.argv = ["serve_real_model.py", "--arch",
                    args.arch if args.arch in ARCHITECTURES else "llama3.2-1b"]
        runpy.run_path("examples/serve_real_model.py", run_name="__main__")
        return

    wl = get_workload(args.arch)
    perf = NPUPerfModel(PAPER_NPU if args.hw == "paper" else TPU_V5E)
    if args.bursty:
        trace = bursty_trace(wl, args.rate * 0.3, args.rate * 2.0,
                             switch_period=args.duration / 6,
                             duration=args.duration, seed=args.seed)
    else:
        trace = poisson_trace(wl, args.rate, args.duration, seed=args.seed)
    policy = build_policy(args.policy, wl, perf, args.sla, args.max_batch,
                          args.window)
    server = InferenceServer(policy, SimExecutor(perf))
    stats = server.run(trace)
    s = stats.summary(sla=args.sla)
    print(f"{wl.name} @ {args.rate:g} r/s ({'bursty' if args.bursty else 'poisson'})"
          f" policy={s['policy']}")
    print(f"  completed {s['completed']}  avg {s['avg_latency_ms']:.2f}ms  "
          f"p99 {s['p99_ms']:.2f}ms  thr {s['throughput_rps']:.0f} r/s  "
          f"SLA viol {s['sla_violation_rate'] * 100:.1f}%  "
          f"avg batch {server.log.avg_batch_size:.1f}")


if __name__ == "__main__":
    main()
