"""Serving launcher.

Two engines, ONE code path — both build a :class:`ServingSession` over the
run-commit scheduling core and print the same summary line:

  * ``--engine sim``  — discrete-event simulation on the NPU latency model
    (any architecture/workload at any load, instantly; virtual time),
  * ``--engine jax``  — the real node-level JAX engine on a reduced model
    (CPU-runnable end-to-end; wall-clock time, so pick an SLA in seconds
    that matches your hardware — the default is auto-scaled).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --policy lazyb --rate 200 --engine sim

Multi-tenant serving: ``--models "llama3.2-1b:0.6,mamba2-2.7b:0.4"``
registers one model per ``name:share`` pair (shares split ``--rate``),
generates a Poisson mixture with independent per-model RNG streams, and
arbitrates committed runs across models with ``--arbiter`` (``rr``
round-robin baseline or the SLA-aware ``least-slack``). Per-model
breakdowns print alongside the aggregate; the sim engine serves every
model through one SimExecutor, the JAX engine builds one reduced-model
engine per name behind a MultiBackend.

Mixed-tier serving: ``--sla-tiers "gold:0.05,bulk:0.5"`` assigns each
request one of the named SLA classes uniformly at random and reports
per-class violation rates alongside the aggregate.

Bounded-memory serving: ``--mem-slots 16`` caps the device KV pool at 16
resident request slots (the sim pays a thrash penalty past the cap; the
JAX engine's paged arena hard-caps at it) and enables memory-aware
admission — overflow defers in the InfQ instead of oversubscribing
device memory. ``--mem-shares "transformer:0.6,gnmt:0.4"`` splits the
pool across the ``--models`` tenants (keys are registered MODEL names,
not SLA tiers) so neither can starve the other of slots; it requires
both ``--models`` and ``--mem-slots``.

Fault-tolerant serving: ``--fault-spec "transient:0.05,straggler:0.1x4"``
wraps the backend in a seeded deterministic chaos layer (per-model form:
``bulk=transient:0.1;gold=straggler:0.02x6``) and arms retry with capped
exponential backoff (``--max-retries``). ``--cancel-expired`` reaps
provably deadline-blown requests mid-flight at run boundaries,
``--max-queue`` bounds the ingress backlog with deadline-aware shedding,
and ``--shed`` arms brownout shedding (drop lowest-``shed_priority``
work while the protected tier's rolling attainment is below floor;
per-model priorities via ``--shed-priorities "gold:1,bulk:0"``). CI
gates on ``--assert-attainment gold:0.5`` (exit 1 below the floor) and
``--assert-no-leak`` (exit 1 if any KV slot stays resident after drain).

``--json-out stats.json`` dumps the full ServeStats — summary, per-class
AND per-model breakdowns, device-time shares, fault/retry/shed
accounting — for CI artifacts and offline analysis.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from ..configs import ARCHITECTURES, get_config
from ..core.arbiter import LeastSlackArbiter, RoundRobinArbiter
from ..core.policies import (CellularBatching, GraphBatching, LazyBatching,
                             Oracle, Serial)
from ..core.request import SLAClass
from ..core.slack import OracleSlackPredictor, SlackPredictor
from ..serving.backend import MultiBackend
from ..serving.faults import FaultInjectingBackend, parse_fault_specs
from ..serving.npu_model import NPUPerfModel, PAPER_NPU, TPU_V5E
from ..serving.session import BrownoutConfig, RetryPolicy, ServingSession
from ..serving.server import SimExecutor
from ..serving.traffic import (bursty_trace, poisson_mixture, poisson_trace,
                               with_sla_classes)
from ..serving.workload import (LengthDist, from_model_config, get_workload)


def build_policy(name: str, wl, perf, sla: float, max_batch: int,
                 window: float):
    if name == "serial":
        return Serial()
    if name == "graphb":
        return GraphBatching(window=window, max_batch=max_batch)
    if name == "cellular":
        return CellularBatching(max_batch=max_batch)
    if name == "lazyb":
        return LazyBatching(SlackPredictor.build([wl], perf, sla),
                            max_batch=max_batch)
    if name == "oracle":
        return Oracle(OracleSlackPredictor(sla, perf), max_batch=max_batch)
    raise KeyError(name)


def parse_tiers(spec: str):
    """Parse ``name:deadline_s[,name:deadline_s...]`` into SLA classes."""
    classes = []
    for part in spec.split(","):
        name, _, deadline = part.strip().partition(":")
        classes.append(SLAClass(name=name, deadline=float(deadline)))
    return classes


def parse_models(spec: str):
    """Parse ``name:share[,name:share...]`` into normalized (name, share)
    pairs (the share splits the aggregate ``--rate``; model names may
    contain dots/dashes, so the LAST colon separates the share)."""
    pairs = []
    for part in spec.split(","):
        name, _, share = part.strip().rpartition(":")
        try:
            value = float(share)
        except ValueError:
            value = float("nan")
        if not name or not value > 0:       # catches NaN, 0, negatives
            raise SystemExit(
                f"--models entry {part!r} must be name:positive_share")
        pairs.append((name, value))
    total = sum(s for _, s in pairs)
    return [(n, s / total) for n, s in pairs]


def _jax_workload(cfg):
    # short prompts / few decode steps: CPU wall-clock budget
    return from_model_config(
        cfg, prompt_dist=LengthDist((6, 8, 10, 12), (0.25,) * 4),
        decode_dist=LengthDist((2, 3, 4, 5), (0.25,) * 4))


def _jax_engine(name, args, max_slots=None):
    """One reduced-model engine + its served workload for ``name``.
    ``max_slots`` is THIS engine's arena cap (per-model engines own
    disjoint pools — multi-tenant callers split the device budget)."""
    from ..serving.engine import JaxEngine
    arch = name if name in ARCHITECTURES else "llama3.2-1b"
    cfg = get_config(arch).reduced()
    return (JaxEngine(cfg, max_len=64, seed=args.seed, max_slots=max_slots),
            _jax_workload(cfg))


def _split_mem_slots(mem_slots, shares, mem_shares):
    """Per-model arena caps for the jax engine: per-model engines hold
    DISJOINT pools, so the one ``--mem-slots`` device budget is split
    structurally — by ``--mem-shares`` when given (normalized; traffic
    share fills unspecified models), else by traffic share. The split is
    budget-exact: caps sum to EXACTLY ``mem_slots`` (largest-remainder
    apportionment, every model >= 1 slot), never oversubscribing the
    device the flag claims to bound. (The arbiter's share caps are for
    SHARED pools like the simulator's and would double-cap disjoint
    ones.)"""
    if mem_slots is None:
        return {}
    if mem_slots < len(shares):
        raise SystemExit(
            f"--mem-slots {mem_slots} < {len(shares)} models: every "
            f"per-model arena needs at least one slot")
    weights = {name: (mem_shares or {}).get(name, share)
               for name, share in shares}
    total_w = sum(weights.values())
    quota = {n: mem_slots * w / total_w for n, w in weights.items()}
    caps = {n: int(q) for n, q in quota.items()}
    # hand leftover slots to the largest fractional remainders
    leftovers = sorted(quota, key=lambda n: quota[n] - caps[n], reverse=True)
    for n in leftovers[:mem_slots - sum(caps.values())]:
        caps[n] += 1
    # a zero-slot arena cannot serve: bump from the largest allocation
    for n in caps:
        while caps[n] == 0:
            caps[max(caps, key=caps.get)] -= 1
            caps[n] += 1
    return caps


def parse_mem_shares(spec):
    """Parse ``name:fraction[,name:fraction...]`` per-model memory shares
    (fractions of the ``--mem-slots`` pool; must sum to <= 1)."""
    if not spec:
        return None
    shares = {}
    for part in spec.split(","):
        name, _, frac = part.strip().rpartition(":")
        try:
            value = float(frac)
        except ValueError:
            value = float("nan")
        if not name or not 0.0 < value <= 1.0:
            raise SystemExit(
                f"--mem-shares entry {part!r} must be name:fraction_in_(0,1]")
        shares[name] = value
    if sum(shares.values()) > 1.0 + 1e-9:
        raise SystemExit(f"--mem-shares oversubscribe the pool: {shares}")
    return shares


def parse_shed_priorities(spec):
    """Parse ``name:priority[,name:priority...]`` per-model shed
    priorities (ints; brownout sheds strictly-lower tiers to protect the
    highest)."""
    if not spec:
        return {}
    out = {}
    for part in spec.split(","):
        name, _, prio = part.strip().rpartition(":")
        try:
            value = int(prio)
        except ValueError:
            name = ""
        if not name:
            raise SystemExit(
                f"--shed-priorities entry {part!r} must be name:int")
        out[name] = value
    return out


def _wrap_faults(backend, args):
    """Seeded chaos layer between the session and the real backend."""
    if not args.fault_spec:
        return backend
    try:
        spec = parse_fault_specs(args.fault_spec)
    except ValueError as e:
        raise SystemExit(f"--fault-spec: {e}")
    seed = args.fault_seed if args.fault_seed is not None else args.seed
    return FaultInjectingBackend(backend, spec, seed=seed)


def _session_kwargs(args):
    """Robustness knobs shared by both launcher paths. Retry arms
    whenever faults can occur (or the budget is set explicitly); all
    knobs default OFF so fault-free runs are bit-identical to before."""
    kw = {"cancel_expired": args.cancel_expired,
          "max_queue": args.max_queue,
          "brownout": BrownoutConfig() if args.shed else None}
    if args.fault_spec or args.max_retries is not None:
        budget = 3 if args.max_retries is None else args.max_retries
        kw["retry"] = RetryPolicy(max_retries=budget)
    return kw


def _check_gates(session, stats, args):
    """CI gates: exit nonzero on a leaked KV slot or attainment below
    the asserted floor (``tier:floor`` judges one SLA class, a bare
    float judges the aggregate)."""
    failed = False
    if args.assert_no_leak:
        mem = session.backend.memory_stats()
        if mem.slots_live != 0:
            print(f"  LEAK: {mem.slots_live} KV slot(s) resident after "
                  f"drain")
            failed = True
        else:
            print("  no leaked KV slots (slots_live=0 after drain)")
    if args.assert_attainment:
        tier, _, floor_s = args.assert_attainment.rpartition(":")
        try:
            floor = float(floor_s)
        except ValueError:
            raise SystemExit(f"--assert-attainment {args.assert_attainment!r}"
                             f" must be [tier:]floor_fraction")
        if tier:
            row = stats.per_class(args.sla).get(tier)
            att = row["sla_attainment"] if row else float("nan")
            label = f"{tier}-tier"
        else:
            att = stats.attainment(args.sla)
            label = "aggregate"
        ok = not np.isnan(att) and att + 1e-12 >= floor
        print(f"  attainment gate: {label} "
              f"{att * 100:.1f}% vs floor {floor * 100:.1f}% -> "
              f"{'PASS' if ok else 'FAIL'}")
        failed = failed or not ok
    if failed:
        raise SystemExit(1)


def _run_session(session, trace, label, args):
    """The shared tail of every launcher path: replay, drain, report."""
    session.duration = trace.duration
    for req in trace.requests:
        session.submit(req)
    stats = session.drain()
    print_summary(label, args, stats, session.log)
    if args.json_out:
        dump_json(args.json_out, stats, session.log, args, session=session)
    _check_gates(session, stats, args)


def print_summary(wl_name: str, args, stats, log):
    s = stats.summary(sla=args.sla)
    kind = "bursty" if args.bursty else "poisson"
    print(f"{wl_name} @ {args.rate:g} r/s ({kind})"
          f" policy={s['policy']} engine={args.engine}")
    print(f"  completed {s['completed']}  avg {s['avg_latency_ms']:.2f}ms  "
          f"p50 {s['p50_ms']:.2f}ms  p99 {s['p99_ms']:.2f}ms  "
          f"thr {s['throughput_rps']:.0f} r/s  "
          f"SLA viol {s['sla_violation_rate'] * 100:.1f}%  "
          f"avg batch {log.avg_batch_size:.1f}")
    extras = [f"{key} {s[key]}"
              for key in ("cancelled", "expired", "failed", "shed",
                          "retried")
              if key in s]
    if extras or log.faults:
        print(f"  faults {log.faults}  " + "  ".join(extras))
    per_class = stats.per_class(args.sla)
    if set(per_class) != {"default"}:
        tiers = "  ".join(f"{name} {row['sla_violation_rate'] * 100:.1f}%"
                          for name, row in per_class.items())
        print(f"  per-tier SLA viol: {tiers}")
    if len(stats.models) > 1:
        print(f"  aggregate SLA attainment "
              f"{stats.attainment(args.sla) * 100:.1f}%")
        for name, row in stats.per_model(args.sla).items():
            busy = log.busy_by_model.get(name, 0.0)
            print(f"  [{name}] completed {row['completed']}  "
                  f"p50 {row['p50_ms']:.2f}ms  p99 {row['p99_ms']:.2f}ms  "
                  f"attain {row['sla_attainment'] * 100:.1f}%  "
                  f"busy {busy * 1e3:.1f}ms")


def dump_json(path: str, stats, log, args, session=None):
    """Full ServeStats snapshot: aggregate summary + per-class + per-model
    breakdowns + device-time shares + fault/retry/shed accounting
    (NaN-safe: NaN serializes as null)."""

    def clean(obj):
        if isinstance(obj, dict):
            return {k: clean(v) for k, v in obj.items()}
        if isinstance(obj, float) and np.isnan(obj):
            return None
        return obj

    doc = {
        # exact reproduction recipe: re-running `python <argv...>` with
        # this seed regenerates the artifact bit-for-bit (sim backend)
        "invocation": {"argv": list(sys.argv), "seed": args.seed},
        "args": {"engine": args.engine, "policy": args.policy,
                 "rate": args.rate, "duration": args.duration,
                 "sla": args.sla, "models": args.models,
                 "arbiter": args.arbiter, "seed": args.seed,
                 "mem_slots": args.mem_slots, "mem_shares": args.mem_shares,
                 "fault_spec": args.fault_spec,
                 "max_retries": args.max_retries,
                 "cancel_expired": args.cancel_expired,
                 "max_queue": args.max_queue, "shed": args.shed,
                 "shed_priorities": args.shed_priorities},
        "summary": clean(stats.summary(sla=args.sla)),
        "per_class": clean(stats.per_class(args.sla)),
        "per_model": clean(stats.per_model(args.sla)),
        "registered_models": stats.models,
        "rejected": stats.rejected,
        "log": {"nodes_executed": log.nodes_executed,
                "runs_executed": log.runs_executed,
                "busy_time": log.busy_time,
                "avg_batch_size": log.avg_batch_size,
                "avg_run_length": log.avg_run_length,
                "busy_by_model": dict(log.busy_by_model),
                "faults": log.faults},
    }
    if session is not None:
        mem = session.backend.memory_stats()
        doc["memory"] = {"slots_live": mem.slots_live,
                         "slots_total": mem.slots_total,
                         "max_slots": mem.max_slots}
        if isinstance(session.backend, FaultInjectingBackend):
            doc["injected_faults"] = session.backend.fault_stats()
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="transformer",
                    help="paper workload or assigned architecture id")
    ap.add_argument("--models", default=None,
                    help='multi-tenant mixture "name:share[,name:share...]"'
                         ' — registers one model per entry; shares split '
                         '--rate (overrides --arch)')
    ap.add_argument("--arbiter", default="least-slack",
                    choices=["rr", "least-slack"],
                    help="cross-model dispatch arbiter (multi-model only)")
    ap.add_argument("--policy", default="lazyb",
                    choices=["serial", "graphb", "cellular", "lazyb",
                             "oracle"])
    ap.add_argument("--engine", default="sim", choices=["sim", "jax"])
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--sla", type=float, default=None,
                    help="global SLA target in seconds (default: 0.1 for "
                         "sim, 60 for jax wall-clock)")
    ap.add_argument("--sla-tiers", default=None,
                    help='mixed per-request SLA classes, e.g. '
                         '"gold:0.05,bulk:0.5" (uniform random assignment)')
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--mem-slots", type=int, default=None,
                    help="bound device KV memory to this many resident "
                         "request slots (sim: thrash penalty past the cap; "
                         "jax: paged-arena hard cap) and turn on "
                         "memory-aware admission")
    ap.add_argument("--mem-shares", default=None,
                    help='per-model memory shares under --mem-slots, keyed '
                         'by registered model name (NOT SLA tier), e.g. '
                         '"transformer:0.6,gnmt:0.4" (fractions of the slot '
                         'pool; keeps one tenant from starving another); '
                         'requires --models and --mem-slots')
    ap.add_argument("--fault-spec", default=None,
                    help='seeded fault injection, e.g. '
                         '"transient:0.05,oom:0.01,straggler:0.1x4" or the '
                         'per-model form "bulk=transient:0.1;gold=..." — '
                         'arms retry/backoff automatically')
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="fault-injection RNG seed (default: --seed)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="retry budget per request before FAILED "
                         "(default 3 when --fault-spec is set)")
    ap.add_argument("--cancel-expired", action="store_true",
                    help="reap provably deadline-blown requests mid-flight "
                         "at run boundaries (frees their KV slots early)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the ingress backlog; overflow sheds the "
                         "lowest-priority / most-hopeless request")
    ap.add_argument("--shed", action="store_true",
                    help="arm brownout shedding: drop lowest-shed-priority "
                         "work while the protected tier's rolling "
                         "attainment is below floor")
    ap.add_argument("--shed-priorities", default=None,
                    help='per-model shed priorities "gold:1,bulk:0" '
                         '(higher survives brownout; requires --models)')
    ap.add_argument("--assert-attainment", default=None,
                    help='CI gate "tier:floor" (or bare "floor" for the '
                         "aggregate): exit 1 when SLA attainment lands "
                         "below the floor fraction")
    ap.add_argument("--assert-no-leak", action="store_true",
                    help="CI gate: exit 1 when any KV slot is still "
                         "resident after drain")
    ap.add_argument("--window", type=float, default=0.025)
    ap.add_argument("--bursty", action="store_true",
                    help="MMPP bursty arrivals instead of Poisson")
    ap.add_argument("--hw", default="paper", choices=["paper", "v5e"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None,
                    help="write the full ServeStats (summary + per-class + "
                         "per-model) to this JSON file")
    args = ap.parse_args()

    perf = NPUPerfModel(PAPER_NPU if args.hw == "paper" else TPU_V5E)
    if args.sla is None:
        # jax serves reduced models on CPU wall-clock: seconds, not ms
        args.sla = 60.0 if args.engine == "jax" else 0.1

    if args.mem_shares and not args.models:
        raise SystemExit("--mem-shares splits the slot pool across the "
                         "--models mixture; pass --models (it has no "
                         "effect on a single-model run)")
    if args.mem_shares and args.mem_slots is None:
        raise SystemExit("--mem-shares describes fractions of the "
                         "--mem-slots pool; pass --mem-slots too")
    if args.shed_priorities and not args.models:
        raise SystemExit("--shed-priorities keys registered model names; "
                         "pass --models (a single-model run has one tier, "
                         "so brownout never sheds)")

    # ---- multi-tenant mixture path -------------------------------------
    if args.models:
        if args.bursty:
            raise SystemExit("--models implies Poisson mixture arrivals; "
                             "drop --bursty")
        shares = parse_models(args.models)
        mem_shares = parse_mem_shares(args.mem_shares)
        if args.engine == "jax":
            # disjoint per-model arenas: split the device slot budget
            # structurally (shares enforced by construction, not the gate)
            caps = _split_mem_slots(args.mem_slots, shares, mem_shares)
            pairs = {name: _jax_engine(name, args, caps.get(name))
                     for name, _ in shares}
            workloads = {name: wl for name, (_, wl) in pairs.items()}
            backend = MultiBackend({name: eng
                                    for name, (eng, _) in pairs.items()})
            arb_shares = None            # already applied per-pool
        else:
            workloads = {name: get_workload(name) for name, _ in shares}
            # model-agnostic: one for all; --mem-slots bounds the one
            # simulated device's KV pool SHARED across every registered
            # model — here the arbiter's shares do the tenant capping
            backend = SimExecutor(perf, max_slots=args.mem_slots)
            arb_shares = mem_shares
        arbiter = (RoundRobinArbiter(mem_shares=arb_shares)
                   if args.arbiter == "rr"
                   else LeastSlackArbiter(sla_default=args.sla,
                                          mem_shares=arb_shares))
        session = ServingSession(backend=_wrap_faults(backend, args),
                                 arbiter=arbiter, seed=args.seed,
                                 **_session_kwargs(args))
        prios = parse_shed_priorities(args.shed_priorities)
        for name, _ in shares:
            wl = workloads[name]
            session.register(name, wl,
                             policy=build_policy(args.policy, wl, perf,
                                                 args.sla, args.max_batch,
                                                 args.window),
                             shed_priority=prios.get(name, 0))
        trace = poisson_mixture(
            [(name, workloads[name], args.rate * share)
             for name, share in shares],
            args.duration, seed=args.seed)
        if args.sla_tiers:
            with_sla_classes(trace, parse_tiers(args.sla_tiers),
                             seed=args.seed)
        # submissions route on each request's mixture model tag
        _run_session(session, trace,
                     "+".join(name for name, _ in shares), args)
        return

    # ---- single-model path ---------------------------------------------
    if args.engine == "jax":
        backend, wl = _jax_engine(args.arch, args, args.mem_slots)
    else:
        wl = get_workload(args.arch)
        backend = SimExecutor(perf, max_slots=args.mem_slots)

    if args.bursty:
        trace = bursty_trace(wl, args.rate * 0.3, args.rate * 2.0,
                             switch_period=args.duration / 6,
                             duration=args.duration, seed=args.seed)
    else:
        trace = poisson_trace(wl, args.rate, args.duration, seed=args.seed)
    if args.sla_tiers:
        with_sla_classes(trace, parse_tiers(args.sla_tiers), seed=args.seed)

    policy = build_policy(args.policy, wl, perf, args.sla, args.max_batch,
                          args.window)
    _run_session(session=ServingSession(policy, _wrap_faults(backend, args),
                                        seed=args.seed,
                                        **_session_kwargs(args)),
                 trace=trace, label=wl.name, args=args)


if __name__ == "__main__":
    main()
