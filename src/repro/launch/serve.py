"""Serving launcher.

Two engines, ONE code path — both build a :class:`ServingSession` over the
run-commit scheduling core and print the same summary line:

  * ``--engine sim``  — discrete-event simulation on the NPU latency model
    (any architecture/workload at any load, instantly; virtual time),
  * ``--engine jax``  — the real node-level JAX engine on a reduced model
    (CPU-runnable end-to-end; wall-clock time, so pick an SLA in seconds
    that matches your hardware — the default is auto-scaled).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --policy lazyb --rate 200 --engine sim

Mixed-tier serving: ``--sla-tiers "gold:0.05,bulk:0.5"`` assigns each
request one of the named SLA classes uniformly at random and reports
per-class violation rates alongside the aggregate.
"""
from __future__ import annotations

import argparse

import numpy as np

from ..configs import ARCHITECTURES, get_config
from ..core.policies import (CellularBatching, GraphBatching, LazyBatching,
                             Oracle, Serial)
from ..core.request import SLAClass
from ..core.slack import OracleSlackPredictor, SlackPredictor
from ..serving.npu_model import NPUPerfModel, PAPER_NPU, TPU_V5E
from ..serving.session import ServingSession
from ..serving.server import SimExecutor
from ..serving.traffic import bursty_trace, poisson_trace, with_sla_classes
from ..serving.workload import (LengthDist, from_model_config, get_workload)


def build_policy(name: str, wl, perf, sla: float, max_batch: int,
                 window: float):
    if name == "serial":
        return Serial()
    if name == "graphb":
        return GraphBatching(window=window, max_batch=max_batch)
    if name == "cellular":
        return CellularBatching(max_batch=max_batch)
    if name == "lazyb":
        return LazyBatching(SlackPredictor.build([wl], perf, sla),
                            max_batch=max_batch)
    if name == "oracle":
        return Oracle(OracleSlackPredictor(sla, perf), max_batch=max_batch)
    raise KeyError(name)


def parse_tiers(spec: str):
    """Parse ``name:deadline_s[,name:deadline_s...]`` into SLA classes."""
    classes = []
    for part in spec.split(","):
        name, _, deadline = part.strip().partition(":")
        classes.append(SLAClass(name=name, deadline=float(deadline)))
    return classes


def print_summary(wl_name: str, args, stats, log):
    s = stats.summary(sla=args.sla)
    kind = "bursty" if args.bursty else "poisson"
    print(f"{wl_name} @ {args.rate:g} r/s ({kind})"
          f" policy={s['policy']} engine={args.engine}")
    print(f"  completed {s['completed']}  avg {s['avg_latency_ms']:.2f}ms  "
          f"p50 {s['p50_ms']:.2f}ms  p99 {s['p99_ms']:.2f}ms  "
          f"thr {s['throughput_rps']:.0f} r/s  "
          f"SLA viol {s['sla_violation_rate'] * 100:.1f}%  "
          f"avg batch {log.avg_batch_size:.1f}")
    per_class = stats.per_class(args.sla)
    if set(per_class) != {"default"}:
        tiers = "  ".join(f"{name} {row['sla_violation_rate'] * 100:.1f}%"
                          for name, row in per_class.items())
        print(f"  per-tier SLA viol: {tiers}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="transformer",
                    help="paper workload or assigned architecture id")
    ap.add_argument("--policy", default="lazyb",
                    choices=["serial", "graphb", "cellular", "lazyb",
                             "oracle"])
    ap.add_argument("--engine", default="sim", choices=["sim", "jax"])
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--sla", type=float, default=None,
                    help="global SLA target in seconds (default: 0.1 for "
                         "sim, 60 for jax wall-clock)")
    ap.add_argument("--sla-tiers", default=None,
                    help='mixed per-request SLA classes, e.g. '
                         '"gold:0.05,bulk:0.5" (uniform random assignment)')
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--window", type=float, default=0.025)
    ap.add_argument("--bursty", action="store_true",
                    help="MMPP bursty arrivals instead of Poisson")
    ap.add_argument("--hw", default="paper", choices=["paper", "v5e"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # ---- workload + backend (the ONLY engine-dependent part) -----------
    perf = NPUPerfModel(PAPER_NPU if args.hw == "paper" else TPU_V5E)
    if args.engine == "jax":
        from ..serving.engine import JaxEngine
        arch = args.arch if args.arch in ARCHITECTURES else "llama3.2-1b"
        cfg = get_config(arch).reduced()
        # short prompts / few decode steps: CPU wall-clock budget
        wl = from_model_config(
            cfg, prompt_dist=LengthDist((6, 8, 10, 12), (0.25,) * 4),
            decode_dist=LengthDist((2, 3, 4, 5), (0.25,) * 4))
        backend = JaxEngine(cfg, max_len=64, seed=args.seed)
        if args.sla is None:
            args.sla = 60.0                       # CPU wall-clock is slow
    else:
        wl = get_workload(args.arch)
        if args.sla is None:
            args.sla = 0.1
        backend = SimExecutor(perf)

    # ---- trace ---------------------------------------------------------
    if args.bursty:
        trace = bursty_trace(wl, args.rate * 0.3, args.rate * 2.0,
                             switch_period=args.duration / 6,
                             duration=args.duration, seed=args.seed)
    else:
        trace = poisson_trace(wl, args.rate, args.duration, seed=args.seed)
    if args.sla_tiers:
        with_sla_classes(trace, parse_tiers(args.sla_tiers), seed=args.seed)

    # ---- one serving loop for both engines -----------------------------
    policy = build_policy(args.policy, wl, perf, args.sla, args.max_batch,
                          args.window)
    session = ServingSession(policy, backend, seed=args.seed)
    session.duration = trace.duration
    for req in trace.requests:
        session.submit(req)
    stats = session.drain()
    print_summary(wl.name, args, stats, session.log)


if __name__ == "__main__":
    main()
