"""Production mesh + parameter/activation sharding-spec derivation.

``make_production_mesh`` builds the target TPU v5e mesh:

  * single-pod:  (data=16, model=16)            — 256 chips
  * multi-pod :  (pod=2, data=16, model=16)     — 512 chips

Parameter specs are derived per-leaf with a deterministic heuristic on top
of a name-based rule table (every model family in ``repro.models`` is
covered by name; the heuristic is the safety net for new layers):

  1. name table picks the *preferred* tensor-parallel dim (heads / ffn /
     vocab / d_inner / lru width ...) -> "model" when divisible,
  2. otherwise the largest remaining dim divisible by the model-axis size,
  3. ZeRO/FSDP: the largest remaining dim divisible by the data-axis size
     -> "data" (train AND serve: weight-gathered serving is what makes
     grok-1-314b fit 16 GB HBM; see DESIGN.md §6),
  4. stacked-layer leading dims (under "blocks"/"tail") are never sharded
     (they are scanned over).

KV-cache specs: batch dim over ("pod","data") when divisible, then the
largest remaining dim over "model" (head_dim for GQA, latent rank for MLA,
ssm heads for Mamba-2) — this is what bounds decode_32k cache memory.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1D 'data' mesh (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e) for the roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

# name -> index of the preferred model-parallel dim (negative = from the end,
# counted on the UNSTACKED shape).
_PREFERRED_MODEL_DIM = {
    # embeddings / head
    "tok": 0,            # (V, d): shard vocab
    "unembed": 1,        # (d, V): shard vocab
    # attention
    "wq": 1, "wk": 1, "wv": 1,      # (d, h, hd): shard heads
    "wo": 0,                         # (h, hd, d): shard heads
    "wq_b": 1,                       # (r, h, qk): shard heads
    "wkv_b": 1,                      # (r, h, nope+v): shard heads
    # dense MLP
    "w_gate": -1, "w_up": -1,        # (d, ff) or (e, d, ff): shard ff
    "w_down": -2,                    # (ff, d) or (e, ff, d): shard ff
    # mamba-2
    "w_z": -1, "w_x": -1,            # (d, di): shard d_inner
    "out_proj": 0,                   # (di, d)
    # rg-lru
    "w_gate_branch": -1, "w_rec_branch": -1,   # (d, w)
    "w_r": -1, "w_i": -1,                       # (w, w)
    "w_out": 0,                                 # (w, d)
}

_STACKED_KEYS = ("blocks", "tail")


def _path_keys(path) -> list:
    return [str(getattr(p, "key", getattr(p, "name", p))) for p in path]


def param_pspec(path, shape: Sequence[int], *, model_n: int, data_n: int,
                fsdp: bool, pod: bool,
                prefer: Optional[dict] = None) -> P:
    keys = _path_keys(path)
    name = keys[-1] if keys else ""
    stacked = any(k in _STACKED_KEYS for k in keys)
    start = 1 if stacked else 0
    ndim = len(shape)
    spec: list = [None] * ndim

    def try_assign(dim: Optional[int], axis: str, n: int) -> bool:
        if dim is None:
            return False
        d = dim + start if dim >= 0 else ndim + dim
        if d < start or d >= ndim or spec[d] is not None:
            return False
        if shape[d] % n or shape[d] < n:
            return False
        spec[d] = axis
        return True

    # 1. preferred model dim by name (experiment overrides take precedence
    #    — e.g. expert parallelism prefers the E dim of MoE weights)
    table = dict(_PREFERRED_MODEL_DIM, **(prefer or {}))
    ok = try_assign(table.get(name), "model", model_n)
    # 2. heuristic fallback: largest unassigned dim divisible by model_n
    if not ok and model_n > 1:
        cand = sorted(range(start, ndim), key=lambda d: -shape[d])
        for d in cand:
            if spec[d] is None and shape[d] % model_n == 0 and shape[d] >= model_n:
                spec[d] = "model"
                break
    # 3. FSDP over data
    if fsdp and data_n > 1:
        cand = sorted(range(start, ndim), key=lambda d: -shape[d])
        for d in cand:
            if spec[d] is None and shape[d] % data_n == 0 and shape[d] >= data_n:
                spec[d] = "data"
                break
    return P(*spec)


def param_pspecs(params_shape, *, mesh: Mesh, fsdp: bool = True,
                 prefer: Optional[dict] = None):
    """Pytree of PartitionSpec matching ``params_shape`` (from eval_shape)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    data_n = sizes.get("data", 1)
    pod = "pod" in sizes

    def one(path, leaf):
        return param_pspec(path, leaf.shape, model_n=model_n, data_n=data_n,
                           fsdp=fsdp, pod=pod, prefer=prefer)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _batch_axes(mesh: Mesh, batch: int):
    """Mesh axes to shard the global batch over (largest divisible prefix)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = [a for a in ("pod", "data") if a in sizes]
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1
    if axes and batch % total == 0 and batch >= total:
        return tuple(axes)
    if "data" in sizes and batch % sizes["data"] == 0 and batch >= sizes["data"]:
        return ("data",)
    return None


def batch_pspecs(specs: dict, *, mesh: Mesh) -> dict:
    """PartitionSpec tree for a dict of (B, ...) input arrays."""
    out = {}
    for k, v in specs.items():
        axes = _batch_axes(mesh, v.shape[0])
        spec = [axes] + [None] * (len(v.shape) - 1)
        out[k] = P(*spec)
    return out


def cache_pspecs(cache_shape, *, mesh: Mesh, prefer: str = "trailing"):
    """KV/state cache specs: dim0=layers (stacked), dim1=batch, then one dim
    over "model".

    prefer="trailing" (baseline): last divisible dim (head_dim / latent rank
    / ssm state) — sharding the cache's time dim puts the decode scatter
    across shards (involuntary full remat in the SPMD partitioner).

    prefer="kv" (§Perf): the kv-head dim (index batch+2 on 4-D attention
    caches), even when not divisible (GSPMD pads) — with grouped GQA decode
    this keeps the whole attention contraction local per device.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    stacked_part, tail_part = cache_shape

    def one(leaf, *, stacked: bool):
        shape = leaf.shape
        ndim = len(shape)
        b_dim = 1 if stacked else 0
        spec: list = [None] * ndim
        axes = _batch_axes(mesh, shape[b_dim])
        spec[b_dim] = axes
        if model_n > 1:
            kv_dim = b_dim + 2
            if prefer == "kv" and ndim == b_dim + 4 and shape[kv_dim] > 1:
                spec[kv_dim] = "model"
                return P(*spec)
            for d in reversed(range(b_dim + 1, ndim)):
                if shape[d] % model_n == 0 and shape[d] >= model_n:
                    spec[d] = "model"
                    break
        return P(*spec)

    stacked_specs = jax.tree.map(lambda l: one(l, stacked=True), stacked_part)
    tail_specs = jax.tree.map(lambda l: one(l, stacked=False), tail_part)
    return (stacked_specs, tail_specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
