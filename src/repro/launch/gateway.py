"""Serve a live HTTP/SSE gateway over a ServingSession.

Network front-end counterpart to :mod:`repro.launch.serve` (trace
replay): builds the same engine/policy/session stack from the same
flags, then serves it at ``POST /v1/generate`` with SSE token
streaming, ``GET /metrics`` Prometheus exposition, health/readiness
probes, bounded-ingress 429 backpressure, and graceful SIGTERM drain.

Examples::

    # sim backend at 50x wall compression, two tiers, bounded ingress
    python -m repro.launch.gateway --policy lazyb --time-scale 50 \
        --sla-tiers gold:0.05,bulk:0.5 --mem-slots 64 --max-queue 256

    # reduced JAX engine on CPU, real wall-clock run latencies
    python -m repro.launch.gateway --engine jax --arch llama3.2-1b \
        --time-scale 1 --port 8080

    curl -N localhost:8080/v1/generate -d \
        '{"model": "transformer", "sla_class": "gold"}'

Exit status: 0 after a clean drain; 1 when ``--assert-no-leak`` finds
resident KV slots after drain or ``--assert-no-stall`` saw the loop
watchdog count an event-loop stall (the CI smoke gates).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys

import numpy as np

from ..core.arbiter import LeastSlackArbiter, RoundRobinArbiter
from ..serving.backend import MultiBackend
from ..serving.gateway import GatewayApp
from ..serving.npu_model import NPUPerfModel, PAPER_NPU, TPU_V5E
from ..serving.server import SimExecutor
from ..serving.session import ServingSession
from ..serving.workload import get_workload
from .serve import (_jax_engine, _session_kwargs, _split_mem_slots,
                    _wrap_faults, build_policy, parse_mem_shares,
                    parse_models, parse_shed_priorities, parse_tiers)


def build_session(args) -> ServingSession:
    """The serve.py session stack, minus the trace: sim or JAX engine,
    single- or multi-model, same policy/memory/fault/shedding knobs."""
    perf = NPUPerfModel(PAPER_NPU if args.hw == "paper" else TPU_V5E)
    if args.models:
        shares = parse_models(args.models)
        mem_shares = parse_mem_shares(args.mem_shares)
        if args.engine == "jax":
            caps = _split_mem_slots(args.mem_slots, shares, mem_shares)
            pairs = {name: _jax_engine(name, args, caps.get(name))
                     for name, _ in shares}
            workloads = {name: wl for name, (_, wl) in pairs.items()}
            backend = MultiBackend({name: eng
                                    for name, (eng, _) in pairs.items()})
            arb_shares = None
        else:
            workloads = {name: get_workload(name) for name, _ in shares}
            backend = SimExecutor(perf, max_slots=args.mem_slots)
            arb_shares = mem_shares
        arbiter = (RoundRobinArbiter(mem_shares=arb_shares)
                   if args.arbiter == "rr"
                   else LeastSlackArbiter(sla_default=args.sla,
                                          mem_shares=arb_shares))
        session = ServingSession(backend=_wrap_faults(backend, args),
                                 arbiter=arbiter, seed=args.seed,
                                 **_session_kwargs(args))
        prios = parse_shed_priorities(args.shed_priorities)
        for name, _ in shares:
            wl = workloads[name]
            session.register(name, wl,
                             policy=build_policy(args.policy, wl, perf,
                                                 args.sla, args.max_batch,
                                                 args.window),
                             shed_priority=prios.get(name, 0))
        return session
    if args.engine == "jax":
        backend, wl = _jax_engine(args.arch, args, args.mem_slots)
    else:
        wl = get_workload(args.arch)
        backend = SimExecutor(perf, max_slots=args.mem_slots)
    policy = build_policy(args.policy, wl, perf, args.sla, args.max_batch,
                          args.window)
    session = ServingSession(backend=_wrap_faults(backend, args),
                             seed=args.seed, **_session_kwargs(args))
    session.register(wl.name, wl, policy=policy)
    return session


def build_app(args, session=None) -> GatewayApp:
    deadlines = {}
    if args.sla_tiers:
        deadlines = {cls.name: cls.deadline
                     for cls in parse_tiers(args.sla_tiers)}
    return GatewayApp(
        session if session is not None else build_session(args),
        host=args.host, port=args.port, time_scale=args.time_scale,
        tick=args.tick_ms / 1e3, request_timeout=args.request_timeout,
        max_inflight=args.max_inflight,
        metrics_log_interval=args.metrics_log_interval,
        default_sla=args.sla, deadline_by_class=deadlines,
        seed=args.seed, drain_grace=args.drain_grace,
        stall_interval=getattr(args, "stall_interval", 0.005),
        stall_threshold=getattr(args, "stall_threshold", 0.25),
        log_enabled=not args.quiet)


def dump_json(path: str, app: GatewayApp, args) -> None:
    """Drained-run artifact: exact invocation, session stats, gateway
    counters — reproducible from the JSON alone."""

    def clean(obj):
        if isinstance(obj, dict):
            return {k: clean(v) for k, v in obj.items()}
        if isinstance(obj, float) and np.isnan(obj):
            return None
        return obj

    stats = app.drained_stats
    mem = app.session.backend.memory_stats()
    doc = {
        "invocation": {"argv": list(sys.argv), "seed": args.seed},
        "args": {"engine": args.engine, "policy": args.policy,
                 "models": args.models, "arch": args.arch,
                 "sla": args.sla, "sla_tiers": args.sla_tiers,
                 "time_scale": args.time_scale,
                 "mem_slots": args.mem_slots,
                 "max_queue": args.max_queue,
                 "max_inflight": args.max_inflight,
                 "fault_spec": args.fault_spec, "seed": args.seed},
        "summary": clean(stats.summary(sla=args.sla)),
        "per_class": clean(stats.per_class(args.sla)),
        "per_model": clean(stats.per_model(args.sla)),
        "gateway": clean(app.metrics.snapshot()),
        "loop": app.sanitizer.stats.as_dict(),
        "memory": {"slots_live": mem.slots_live,
                   "slots_total": mem.slots_total,
                   "max_slots": mem.max_slots},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {path}", file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="listen port (0 = ephemeral, printed in the "
                         "ready log record)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="session-clock seconds per wall second (sim "
                         "backend: >1 compresses wall time; jax: keep 1)")
    ap.add_argument("--tick-ms", type=float, default=2.0,
                    help="pump interval in wall ms")
    ap.add_argument("--request-timeout", type=float, default=None,
                    help="per-request wall-clock budget in seconds; "
                         "expiry cancels the handle and reports 408")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="gateway in-flight soft bound; beyond it new "
                         "work gets 429 + Retry-After (protected-"
                         "priority requests keep headroom)")
    ap.add_argument("--metrics-log-interval", type=float, default=None,
                    help="emit a periodic metrics log record every N "
                         "wall seconds")
    ap.add_argument("--drain-grace", type=float, default=5.0,
                    help="max wall seconds to wait for handlers to "
                         "flush after drain")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress JSON access/lifecycle logs")
    ap.add_argument("--json-out", default=None,
                    help="write the drained-run artifact to this file")
    ap.add_argument("--assert-no-leak", action="store_true",
                    help="exit 1 when KV slots remain resident after "
                         "drain (CI smoke gate)")
    ap.add_argument("--stall-interval", type=float, default=0.005,
                    help="event-loop stall watchdog probe period in "
                         "wall seconds")
    ap.add_argument("--stall-threshold", type=float, default=0.25,
                    help="wakeup lag above this many wall seconds "
                         "counts as an event-loop stall")
    ap.add_argument("--assert-no-stall", action="store_true",
                    help="exit 1 when the watchdog counted any "
                         "event-loop stall (CI smoke gate)")
    # session stack (mirrors launch/serve.py)
    ap.add_argument("--arch", default="transformer")
    ap.add_argument("--models", default=None,
                    help='multi-tenant mixture "name:share[,...]"')
    ap.add_argument("--arbiter", default="least-slack",
                    choices=["rr", "least-slack"])
    ap.add_argument("--policy", default="lazyb",
                    choices=["serial", "graphb", "cellular", "lazyb",
                             "oracle"])
    ap.add_argument("--engine", default="sim", choices=["sim", "jax"])
    ap.add_argument("--sla", type=float, default=None,
                    help="global SLA target in seconds (default: 0.1 "
                         "sim, 60 jax)")
    ap.add_argument("--sla-tiers", default=None,
                    help='SLA classes requests may ask for, e.g. '
                         '"gold:0.05,bulk:0.5"')
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--window", type=float, default=0.025)
    ap.add_argument("--mem-slots", type=int, default=None)
    ap.add_argument("--mem-shares", default=None)
    ap.add_argument("--fault-spec", default=None)
    ap.add_argument("--fault-seed", type=int, default=None)
    ap.add_argument("--max-retries", type=int, default=None)
    ap.add_argument("--cancel-expired", action="store_true")
    ap.add_argument("--max-queue", type=int, default=None)
    ap.add_argument("--shed", action="store_true")
    ap.add_argument("--shed-priorities", default=None)
    ap.add_argument("--hw", default="paper", choices=["paper", "v5e"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.sla is None:
        args.sla = 60.0 if args.engine == "jax" else 0.1

    app = build_app(args)
    asyncio.run(app.run())

    stats = app.drained_stats
    summary = stats.summary(sla=args.sla)
    print(f"gateway drained: completed {summary['completed']}  "
          f"viol {summary.get('sla_violation_rate', float('nan')) * 100:.1f}%"
          f"  429s {int(app.metrics.backpressure.total())}",
          file=sys.stderr)
    loop_stats = app.sanitizer.stats
    print(f"event loop: {loop_stats.ticks} probes  "
          f"{loop_stats.stalls} stall(s)  "
          f"max lag {loop_stats.max_lag_s * 1e3:.1f}ms  "
          f"lag p99 {loop_stats.lag_p99_s() * 1e3:.1f}ms",
          file=sys.stderr)
    if args.json_out:
        dump_json(args.json_out, app, args)
    if args.assert_no_stall and loop_stats.stalls:
        print(f"STALL: {loop_stats.stalls} event-loop stall(s) over "
              f"{args.stall_threshold}s (max lag "
              f"{loop_stats.max_lag_s:.3f}s)", file=sys.stderr)
        return 1
    if args.assert_no_leak:
        mem = app.session.backend.memory_stats()
        if mem.slots_live != 0:
            print(f"LEAK: {mem.slots_live} KV slot(s) resident after "
                  f"drain", file=sys.stderr)
            return 1
        print("no leaked KV slots (slots_live=0 after drain)",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
