"""Training launcher.

On real hardware this runs the sharded train step on the production mesh;
in this container it runs reduced configs on CPU end-to-end (the same code
path — the mesh is just smaller).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --reduced --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCHITECTURES, get_config
from ..data.pipeline import DataConfig, TokenPipeline
from ..models.model import Model, RuntimeFlags
from ..sharding import make_rules, use_rules
from ..training import OptimizerConfig, train_loop
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES),
                    default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, RuntimeFlags(dtype=jnp.float32, remat=False))
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    batch_size=args.batch))
    mesh = make_host_mesh()
    rules = make_rules(mesh, "train")
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    with mesh, use_rules(rules):
        state, log = train_loop(model, opt_cfg, iter(data), args.steps,
                                checkpoint_path=args.checkpoint,
                                log_every=args.log_every)
    first, last = log.losses[0], log.losses[-1]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({(first - last) / first * 100:.1f}% reduction) "
          f"in {log.wall[-1]:.1f}s")
    if not last < first:
        # smoke gate: survives python -O, exits nonzero for the harness
        raise SystemExit(
            f"training smoke FAILED: loss did not decrease "
            f"({first:.4f} -> {last:.4f} over {args.steps} steps)")


if __name__ == "__main__":
    main()
