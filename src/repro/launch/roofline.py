"""Roofline analysis from the compiled dry-run (deliverable (g)).

XLA's ``cost_analysis`` counts a ``scan`` while-body ONCE (verified
empirically: llama train flops ≈ head + one layer), so the full scanned
dry-run cannot give exact FLOP/byte totals. Instead we lower *unrolled
probes* at 1× and 2× the layer-pattern size and extrapolate linearly —
every per-layer cost (flops, bytes, collective traffic) is exactly linear
in depth, embedding/head/optimizer-fixed costs are the intercept:

    per_unit = (C(2·base) - C(base)) / base
    total    = C(base) - base·per_unit + num_layers·per_unit

(base = hybrid block-pattern length, else 1; RecurrentGemma's 2 trailing
rec layers are counted at the average-group rate — documented ~2% error.)

Terms (TPU v5e constants in ``mesh.py``; all quantities below are
per-device, which equals the global/chips normalization of the brief):

    compute    = flops_per_device / 197e12
    memory     = bytes_per_device / 819e9
    collective = collective_operand_bytes_per_device / 50e9

Usage:
  python -m repro.launch.roofline --arch llama3.2-1b --shape train_4k --out results/roofline
  python -m repro.launch.roofline --all --out results/roofline
  python -m repro.launch.roofline --report results/roofline --dryrun results/dryrun
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import time

from ..configs import ARCHITECTURES, INPUT_SHAPES, get_config, get_shape
from ..models.cost import model_flops
from . import hlo_stats
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS, make_production_mesh
from .steps import lower_combo


def _probe(arch: str, shape: str, mesh, L: int, *, extra_flags=None,
           fsdp_override=None, rules_overrides=None, **kw) -> dict:
    flags = {"use_scan": False}
    if extra_flags:
        flags.update(extra_flags)
    lowered, _ = lower_combo(arch, shape, mesh,
                             cfg_overrides={"num_layers": L},
                             flag_overrides=flags,
                             fsdp_override=fsdp_override,
                             rules_overrides=rules_overrides, **kw)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = hlo_stats.collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": sum(v["bytes"] for v in coll.values()),
        "coll": coll,
        "arg_bytes": float(getattr(mem, "argument_size_in_bytes", 0) or 0),
    }


def probe_costs(arch: str, shape: str, *, multi_pod: bool = False,
                extra_flags=None, fsdp_override=None,
                rules_overrides=None, verbose=True,
                mesh_shape=None, **kw) -> dict:
    """Linear-extrapolated per-device costs for the full-depth model.

    ``mesh_shape``: ((dims...), (axis names...)) overrides the production
    mesh — used by §Perf experiments that re-shape the logical mesh
    (e.g. the decode-optimized (data=32, model=8))."""
    import jax
    cfg = get_config(arch)
    if mesh_shape is not None:
        mesh = jax.make_mesh(*mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    base = len(cfg.hybrid.block_pattern) if cfg.hybrid is not None else 1
    # wall-clock times the roofline PROBE itself (reported as probe_s);
    # roofline cost estimates come from compiled HLO analysis, not timing
    t0 = time.perf_counter()  # reprolint: disable=wallclock-taint
    c1 = _probe(arch, shape, mesh, base, extra_flags=extra_flags,
                fsdp_override=fsdp_override, rules_overrides=rules_overrides,
                **kw)
    c2 = _probe(arch, shape, mesh, 2 * base, extra_flags=extra_flags,
                fsdp_override=fsdp_override, rules_overrides=rules_overrides,
                **kw)
    dt = time.perf_counter() - t0  # reprolint: disable=wallclock-taint

    units = cfg.num_layers / base
    out = {"arch": arch, "shape": shape,
           "mesh": "pod2x16x16" if multi_pod else "pod16x16",
           "probe_s": round(dt, 1)}
    for key in ("flops", "bytes", "coll_bytes"):
        per_unit = (c2[key] - c1[key]) / base
        fixed = c1[key] - base * per_unit
        out[key] = fixed + cfg.num_layers * per_unit
        out[key + "_fixed"] = fixed
        out[key + "_per_layer"] = per_unit
    # per-kind collective extrapolation
    kinds = set(c1["coll"]) | set(c2["coll"])
    out["coll_kinds"] = {}
    for k in sorted(kinds):
        b1 = c1["coll"].get(k, {}).get("bytes", 0)
        b2 = c2["coll"].get(k, {}).get("bytes", 0)
        pu = (b2 - b1) / base
        out["coll_kinds"][k] = b1 - base * pu + cfg.num_layers * pu
    return out


_HINTS = {
    "compute": ("compute-bound: raise MXU efficiency — fuse small ops, "
                "larger per-device tile of the dominant matmul, or shed "
                "redundant (remat) FLOPs"),
    "memory": ("HBM-bound: cut activation/weight traffic — fuse elementwise "
               "chains (Pallas), reuse KV blocks in VMEM, or quantize "
               "weights/cache"),
    "collective": ("ICI-bound: reshard to shrink per-layer collectives — "
                   "avoid weight all-gathers (no-FSDP serving), overlap "
                   "collectives with compute, or move the axis the traffic "
                   "crosses"),
}


def analytic_bytes(arch: str, shape_name: str, n_dev: int) -> float:
    """Analytic per-device HBM traffic (weights + activations + KV), from
    the cost model — the cross-check for the HLO 'bytes accessed' term,
    which the CPU backend inflates (less fusion than TPU; bf16 scatters are
    promoted to f32 copy chains). Train ≈ 3x forward traffic."""
    from ..models.cost import step_costs
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    phase = {"train": "train", "prefill": "prefill",
             "decode": "decode"}[shape.kind]
    costs = step_costs(cfg, phase, shape.global_batch, shape.seq_len)
    total = sum(c.weight_bytes + c.act_bytes for c in costs)
    if shape.kind == "train":
        total *= 3.0
    return total / n_dev


def terms_record(probe: dict, *, train: bool) -> dict:
    """Roofline terms + MODEL_FLOPS cross-check for one probed combo."""
    cfg = get_config(probe["arch"])
    shape = get_shape(probe["shape"])
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mf = model_flops(cfg, tokens, train=shape.kind == "train")
    n_dev = 512 if probe["mesh"] == "pod2x16x16" else 256
    hlo_global = probe["flops"] * n_dev
    compute = probe["flops"] / PEAK_FLOPS
    memory = probe["bytes"] / HBM_BW
    collective = probe["coll_bytes"] / ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {
        **probe,
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "mfu_bound": (mf / n_dev / PEAK_FLOPS) / total if total else 0.0,
        "analytic_memory_s": analytic_bytes(probe["arch"], probe["shape"],
                                            n_dev) / HBM_BW,
        "hint": _HINTS[dom],
    }


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def render_table(records) -> str:
    rows = ["| arch | shape | compute | memory | collective | bound | "
            "useful FLOPs | roofline MFU |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_seconds(r['compute_s'])} "
            f"| {fmt_seconds(r['memory_s'])} | {fmt_seconds(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['useful_ratio'] * 100:.0f}% "
            f"| {r['mfu_bound'] * 100:.0f}% |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES))
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--report", metavar="DIR",
                    help="render the markdown table from probe JSONs")
    args = ap.parse_args()

    if args.report:
        recs = []
        for fn in sorted(os.listdir(args.report)):
            if fn.endswith(".json"):
                with open(os.path.join(args.report, fn)) as f:
                    recs.append(json.load(f))
        print(render_table(recs))
        return

    combos = ([(a, s) for a in sorted(ARCHITECTURES) for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in combos:
        try:
            p = probe_costs(arch, shape, multi_pod=args.multi_pod)
            rec = terms_record(p, train=shape == "train_4k")
            print(f"[{arch} × {shape}] compute {fmt_seconds(rec['compute_s'])} "
                  f"memory {fmt_seconds(rec['memory_s'])} "
                  f"collective {fmt_seconds(rec['collective_s'])} "
                  f"-> {rec['dominant']} (useful {rec['useful_ratio']:.2f}, "
                  f"probe {p['probe_s']}s)")
        except Exception as e:    # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "error": f"{type(e).__name__}: {e}"}
            print(f"[{arch} × {shape}] FAIL {rec['error']}")
        fn = f"{arch}__{shape}__{rec.get('mesh', 'pod16x16')}.json"
        with open(os.path.join(args.out, fn), "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
