"""Multi-pod dry-run driver (deliverable (e)).

Proves the distribution config is coherent without real hardware: for every
(architecture × input shape) the step function must ``.lower().compile()``
on BOTH production meshes — (data=16, model=16) single-pod and
(pod=2, data=16, model=16) multi-pod — and we record memory / cost /
collective statistics for §Dry-run and §Roofline of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
  python -m repro.launch.dryrun --arch grok-1-314b --shape decode_32k --multi-pod
"""
# The VERY FIRST lines — before ANY other import (jax locks the device count
# on first init). 512 placeholder host devices cover both meshes.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHITECTURES, INPUT_SHAPES
from . import hlo_stats
from .mesh import make_production_mesh
from .steps import lower_combo


def run_one(arch: str, shape: str, *, multi_pod: bool,
            flag_overrides=None, fsdp_override=None,
            rules_overrides=None, verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # wall-clock is the MEASURED quantity here (lower/compile timing
        # of an AOT dry run) — it never feeds the virtual-time simulator
        t0 = time.perf_counter()  # reprolint: disable=wallclock-taint
        lowered, combo = lower_combo(arch, shape, mesh,
                                     flag_overrides=flag_overrides,
                                     fsdp_override=fsdp_override,
                                     rules_overrides=rules_overrides)
        t1 = time.perf_counter()  # reprolint: disable=wallclock-taint
        compiled = lowered.compile()
        t2 = time.perf_counter()  # reprolint: disable=wallclock-taint

        mem = compiled.memory_analysis()
        mem_rec = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_rec[f] = int(v)
        cost = compiled.cost_analysis() or {}
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))}
        coll = hlo_stats.collective_stats(compiled.as_text())

        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            n_devices=int(mesh.devices.size),
            memory=mem_rec,
            cost={k: cost_rec[k] for k in ("flops", "bytes accessed",
                                           "transcendentals")
                  if k in cost_rec},
            collectives=coll,
        )
        if verbose:
            print(f"[{arch} × {shape} × {mesh_name}] OK  "
                  f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
            print("  memory_analysis:", mem_rec)
            print("  cost_analysis:  ", rec["cost"])
            print("  collectives:    ",
                  {k: f"{v['count']}x/{v['bytes']/1e9:.2f}GB"
                   for k, v in coll.items()})
    except Exception as e:          # noqa: BLE001 — record, don't crash sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
        if verbose:
            print(f"[{arch} × {shape} × {mesh_name}] FAIL: {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the (pod=2, data=16, model=16) mesh")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch × shape) on this mesh")
    ap.add_argument("--out", default=None,
                    help="directory for per-combo JSON records")
    args = ap.parse_args()

    if args.all:
        combos = [(a, s) for a in sorted(ARCHITECTURES)
                  for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    n_ok = 0
    for arch, shape in combos:
        rec = run_one(arch, shape, multi_pod=args.multi_pod)
        n_ok += rec["ok"]
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fn = f"{arch}__{shape}__{rec['mesh']}.json"
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(rec, f, indent=1)
    print(f"\n{n_ok}/{len(combos)} combinations lowered+compiled OK")
    raise SystemExit(0 if n_ok == len(combos) else 1)


if __name__ == "__main__":
    main()
