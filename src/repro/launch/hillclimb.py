"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> record.

Three targets chosen from the 40-pair baseline table (EXPERIMENTS.md
§Roofline): the worst roofline fraction (minicpm3-4b × prefill_32k), the
most collective-bound (granite-moe-3b-a800m × train_4k), and the pair most
representative of the paper's own technique — lazily-merged ragged decode
(qwen2.5-32b × decode_32k).

Every experiment re-probes the full roofline terms with one named change;
results land in results/perf/ and are summarized in EXPERIMENTS.md §Perf.

  python -m repro.launch.hillclimb --target minicpm   # or granite / qwen / all
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json

from .roofline import fmt_seconds, probe_costs, terms_record

# target -> list of (label, hypothesis, probe kwargs). Order matters: each
# entry is one hillclimb iteration; labels starting with '+' stack on the
# previous accepted change.
EXPERIMENTS = {
    "qwen": {
        "arch": "qwen2.5-32b", "shape": "decode_32k",
        "steps": [
            ("baseline", "paper-faithful decode: repeat_kv GQA, "
             "head_dim-sharded cache", {}),
            ("grouped", "repeat_kv materializes H/KV=5x the cache and the "
             "hd-sharded contraction all-reduces (B,H,T) f32 scores per "
             "layer; grouped einsum removes the repeat (expect memory "
             "term ~-60%)",
             dict(extra_flags={"grouped_decode": True})),
            ("grouped+donate", "production serving donates the cache "
             "(in-place update); without donation the dry-run double-counts "
             "a full cache copy into fresh output buffers (expect memory "
             "term down, collectives unchanged)",
             dict(extra_flags={"grouped_decode": True}, donate_cache=True)),
            ("grouped+mesh32x8", "REFUTED kv-pad idea: in_shardings needs "
             "divisibility, kv=8 cannot shard over model=16. Instead "
             "re-shape the logical mesh to (data=32, model=8): kv, heads "
             "(40), and d_ff all divide 8, so with the grouped einsum the "
             "whole attention is local per device — expect the per-layer "
             "scores all-reduce (42MB f32) to disappear",
             dict(extra_flags={"grouped_decode": True}, donate_cache=True,
                  cache_prefer="kv",
                  mesh_shape=((32, 8), ("data", "model")))),
            ("+int8kv", "the remaining honest memory term is cache "
             "streaming; int8 symmetric per-(token,kv-head) quantization "
             "halves cache capacity AND read bytes (expect argument size "
             "-~50% and the analytic memory term to halve; accuracy cost "
             "bounded in tests)",
             dict(extra_flags={"grouped_decode": True, "kv_quant": True},
                  donate_cache=True, cache_prefer="kv",
                  mesh_shape=((32, 8), ("data", "model")))),
        ],
    },
    "minicpm": {
        "arch": "minicpm3-4b", "shape": "prefill_32k",
        "steps": [
            ("baseline", "paper-faithful MLA prefill: materialized per-head "
             "K/V, heads (40) not divisible by model axis (16) -> padded "
             "head sharding, scores partial-summed across shards", {}),
            ("absorbed", "latent-space attention: K-side chunk reads drop "
             "from (T,H,96+64) to (T,R+P)=(T,288) (~13x) and no per-head "
             "K/V hits HBM (expect memory term -80%+)",
             dict(extra_flags={"mla_absorbed": True})),
            ("absorbed+headsrep", "the remaining all-reduce comes from the "
             "padded 40-head sharding of q/scores; replicating activations "
             "over heads keeps every score matmul local (expect collective "
             "-90% at ~2x compute)",
             dict(extra_flags={"mla_absorbed": True},
                  rules_overrides={"heads": None})),
            ("absorbed+seqpar", "alternative: shard the residual stream "
             "over seq (context parallelism) instead of heads — activations "
             "16x smaller per device, attention gathers the latent cache "
             "(S*288 per chunk) instead of activations",
             dict(extra_flags={"mla_absorbed": True},
                  rules_overrides={"heads": None, "act_seq": "model"})),
            ("seqpar-only", "ablation: is sequence parallelism alone enough, "
             "or does the absorbed form contribute? (separates the two "
             "factors of the 16x win)",
             dict(rules_overrides={"act_seq": "model"})),
        ],
    },
    "granite": {
        "arch": "granite-moe-3b-a800m", "shape": "train_4k",
        "steps": [
            ("baseline", "paper-faithful MoE train: expert FFN sharded over "
             "model; TP sum all-reduces the (e,cap,d) expert buffer "
             "(~10x larger than the (t,d) token output)", {}),
            ("moeout-rs", "constrain out_buf sharded over d: the TP "
             "all-reduce becomes a reduce-scatter and the linear combine "
             "defers the gather to the (t,d) output (expect collective "
             "~-50%)",
             dict(rules_overrides={"moe_out": "model"})),
            ("moeout+seqpar", "+ Megatron sequence parallelism on the "
             "residual stream: saved activations and norm/residual traffic "
             "shard 16x over model (expect memory term down, all-gathers "
             "localized around attention/moe)",
             dict(rules_overrides={"moe_out": "model", "act_seq": "model"})),
            ("expert-parallel", "neither TP tweak moved the bound: the "
             "(e,cap,d) buffers are inherently TP-hostile (d_ff=512 gives "
             "32-wide shards). Re-shape to (data=32, model=8) where E=40 "
             "divides 8 and shard the EXPERT dim instead: each device "
             "holds 5 whole experts (no ff partial sums at all); dispatch "
             "becomes the GShard all-to-all pattern (expect collective "
             "down several x)",
             dict(mesh_shape=((32, 8), ("data", "model")),
                  param_prefer={"w_gate": 0, "w_up": 0, "w_down": 0},
                  rules_overrides={"experts": "model", "expert_ffn": None})),
        ],
    },
}


def run_target(name: str, out_dir: str = "results/perf"):
    spec = EXPERIMENTS[name]
    os.makedirs(out_dir, exist_ok=True)
    print(f"\n=== hillclimb {name}: {spec['arch']} × {spec['shape']} ===")
    prev = None
    for label, hypothesis, kw in spec["steps"]:
        p = probe_costs(spec["arch"], spec["shape"], **kw)
        rec = terms_record(p, train=spec["shape"] == "train_4k")
        rec["label"] = label
        rec["hypothesis"] = hypothesis
        fn = f"{spec['arch']}__{spec['shape']}__{label}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
        line = (f"[{label:18s}] compute {fmt_seconds(rec['compute_s']):>9s} "
                f"memory {fmt_seconds(rec['memory_s']):>9s} "
                f"collective {fmt_seconds(rec['collective_s']):>9s} "
                f"dom={rec['dominant']}")
        if prev is not None:
            tot_p = max(prev["compute_s"], prev["memory_s"], prev["collective_s"])
            tot_n = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
            line += f"  bound {tot_p / tot_n:5.2f}x vs prev"
        print(line)
        prev = rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--target", choices=[*EXPERIMENTS, "all"], default="all")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    targets = list(EXPERIMENTS) if args.target == "all" else [args.target]
    for t in targets:
        run_target(t, args.out)


if __name__ == "__main__":
    main()
