"""Per-(architecture × input-shape) step builders for the dry-run.

One function — ``lower_combo`` — is the single entry point: it builds the
model, derives shardings (``repro.launch.mesh``), installs the logical-axis
rules, and returns the ``jax.stages.Lowered`` for the requested phase:

  * ``train_4k``     -> ``train_step(state, batch)``          (AdamW update)
  * ``prefill_32k``  -> ``prefill_step(params, batch)``       (logits + cache)
  * ``decode_32k``   -> ``serve_step(params, cache, token, pos)`` (ONE token)
  * ``long_500k``    -> ``serve_step`` with the sliding-window cache
                        (attention archs) / constant state (SSM, hybrid)

Everything is ShapeDtypeStruct-driven: no parameter or cache is ever
allocated (the dry-run pattern from the brief).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config, get_shape
from ..configs.base import InputShape, ModelConfig
from ..data.pipeline import make_batch_specs
from ..models.model import Model, RuntimeFlags
from ..sharding import make_rules, use_rules
from ..training import OptimizerConfig, init_state, make_train_step
from . import mesh as M


def input_specs(arch: str, shape_name: str = "train_4k") -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one phase."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    return make_batch_specs(cfg, shape)


def make_flags(cfg: ModelConfig, shape: InputShape, *,
               overrides: Optional[dict] = None) -> RuntimeFlags:
    kw = dict(use_scan=True)
    if shape.kind == "train":
        kw["remat"] = True
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        # sub-quadratic long-context variant: ring-buffer sliding window
        kw["window"] = cfg.long_context_window
    if cfg.moe is not None and shape.kind == "decode":
        kw["moe_group_rows"] = max(1, shape.global_batch // 32)
    if overrides:
        kw.update(overrides)
    return RuntimeFlags(**kw)


def serve_fsdp(cfg: ModelConfig, model_n: int, *,
               budget_bytes: float = 8e9) -> bool:
    """Weight-gather (ZeRO-inference) serving only when pure tensor
    parallelism cannot fit the parameters (grok-1-314b)."""
    return cfg.param_count() * 2 / model_n > budget_bytes


@dataclass
class Combo:
    """Everything needed to lower one (arch × shape × mesh) combination."""
    cfg: ModelConfig
    shape: InputShape
    mesh: Mesh
    model: Model
    fn: object                 # the step callable
    args: tuple                # ShapeDtypeStruct pytrees
    in_shardings: tuple
    donate: tuple = ()


def build_combo(arch: str, shape_name: str, mesh: Mesh, *,
                flag_overrides: Optional[dict] = None,
                fsdp_override: Optional[bool] = None,
                rules_overrides: Optional[dict] = None,
                cfg_overrides: Optional[dict] = None,
                cache_prefer: str = "trailing",
                param_prefer: Optional[dict] = None) -> Combo:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    flags = make_flags(cfg, shape, overrides=flag_overrides)
    model = Model(cfg, flags)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    key = jax.random.key(0)
    batch = make_batch_specs(cfg, shape)
    batch_sh = M.named(mesh, M.batch_pspecs(batch, mesh=mesh))

    if shape.kind == "train":
        opt_cfg = OptimizerConfig()
        state_shape = jax.eval_shape(lambda: init_state(model, key))
        state_spec = M.param_pspecs(state_shape, mesh=mesh, fsdp=True,
                                    prefer=param_prefer)
        state_sh = M.named(mesh, state_spec)
        fn = make_train_step(model, opt_cfg)
        return Combo(cfg, shape, mesh, model, fn,
                     (state_shape, batch), (state_sh, batch_sh))

    fsdp = serve_fsdp(cfg, model_n) if fsdp_override is None else fsdp_override
    params_shape = jax.eval_shape(model.init, key)
    params_sh = M.named(mesh, M.param_pspecs(params_shape, mesh=mesh,
                                             fsdp=fsdp, prefer=param_prefer))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"],
                                 prefix=batch.get("prefix"),
                                 max_len=shape.seq_len)

        return Combo(cfg, shape, mesh, model, prefill_step,
                     (params_shape, batch), (params_sh, batch_sh))

    # decode: ONE new token against a seq_len-deep cache
    B = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len))
    cache_sh = M.named(mesh, M.cache_pspecs(cache_shape, mesh=mesh,
                                            prefer=cache_prefer))

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = NamedSharding(mesh, M.batch_pspecs({"t": tok}, mesh=mesh)["t"])
    return Combo(cfg, shape, mesh, model, serve_step,
                 (params_shape, cache_shape, tok, pos),
                 (params_sh, cache_sh, tok_sh, tok_sh))


def lower_combo(arch: str, shape_name: str, mesh: Mesh, *,
                donate_cache: bool = False, **kw):
    """Lower (but do not compile) one combination on ``mesh``.

    ``donate_cache``: donate the KV-cache argument of decode steps (the
    production serving behavior — the cache updates in place instead of
    being copied into a fresh output buffer).
    """
    combo = build_combo(arch, shape_name, mesh, **kw)
    rules = make_rules(mesh, "train" if combo.shape.kind == "train" else "serve")
    rk = kw.get("rules_overrides")
    if rk:
        rules.mapping.update(rk)
    donate = (1,) if (donate_cache and combo.shape.kind == "decode") else ()
    with mesh, use_rules(rules):
        jitted = jax.jit(combo.fn, in_shardings=combo.in_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*combo.args)
    return lowered, combo
