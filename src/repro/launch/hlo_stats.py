"""Post-optimization HLO statistics: collective-traffic extraction.

``collective_bytes`` is NOT in ``compiled.cost_analysis()`` — we parse the
compiled module text and sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(the §Roofline methodology from the brief).

Operand shapes are resolved in two steps: shapes printed inline inside the
instruction's parentheses when present, otherwise a symbol table built from
every instruction definition in the module.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(type_str))


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {count, bytes} summed over the module.

    Bytes are the *operand* sizes of each collective instruction.
    """
    # symbol table: instruction name -> result type bytes
    sym: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _op = m.groups()
            sym[name] = _type_bytes(type_str)

    stats: Dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = next((c for c in COLLECTIVE_OPS
                     if op == c or op.startswith(c + ".")
                     or op.startswith(c + "-start")), None)
        if kind is None:
            continue
        # operand segment: inside the first balanced parens after the op name
        start = line.index(op + "(") + len(op) + 1
        depth, i = 1, start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = line[start:i - 1]
        inline = _SHAPE_RE.findall(operands)
        if inline:
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in inline)
        else:
            nbytes = sum(sym.get(nm, 0)
                         for nm in _OPERAND_RE.findall(operands))
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += nbytes
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())
