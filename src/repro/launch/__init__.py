"""Launcher: production mesh, dry-run driver, roofline extraction.

NOTE: importing this package never touches jax device state —
``make_production_mesh`` is a function, and the 512-placeholder-device
XLA flag is set only by ``dryrun.py`` when run as a script.
"""
