"""Checker 1 — ``sync-point``: host-device syncs in the engine hot path.

The PR 2 contract: a committed run executes async on device and the
engine synchronizes exactly ONCE, at the run boundary. Every construct
below forces a host-device sync (device transfer or blocking wait), so
inside the run-execution hot paths of ``serving/engine.py`` each one is a
hidden extra sync that silently serializes the fused pipeline:

  * ``<expr>.item()`` / ``<expr>.tolist()``       — device -> host scalar,
  * ``jax.block_until_ready(...)`` (any spelling) — blocking wait,
  * ``jax.device_get(...)``                       — device -> host copy,
  * ``np.asarray(...)`` / ``np.array(...)`` / ``np.copy(...)`` — numpy
    coercion of a (potentially device) array is a transfer,
  * ``bool(...)`` / ``int(...)`` / ``float(...)`` on a non-trivial
    expression — Python scalar coercion of a traced/device value blocks.

The ONE legitimate run-boundary sync carries a ``# reprolint:
disable=sync-point`` annotation; anything unannotated is a regression.
Hot paths are the run-execution call tree, named explicitly below —
single-node ``execute`` is the degenerate one-sync-per-*node* reference
path and is exempt by design.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile, dotted_name, is_engine_file

#: JaxEngine methods on the fused run-execution path (plus the nested
#: closures they define). ``execute`` (single-node reference) and the
#: rare-by-design arena reclamation helpers are deliberately absent.
HOT_FUNCTIONS = {
    "execute_run",
    "_run_prefill_chunk",
    "_prefill_groups",
    "_entry_x",
    "_batched_x",
    "_flush_xbatch",
    "_batched_slots",
    "_offs",
    "_chunk_run",
}

_SYNC_METHOD_CALLS = {"item", "tolist"}
_SYNC_DOTTED = {
    "jax.block_until_ready",
    "jax.device_get",
    "np.asarray", "np.array", "np.copy",
    "numpy.asarray", "numpy.array", "numpy.copy",
}
_SCALAR_COERCIONS = {"bool", "int", "float"}


class SyncPointChecker(Checker):
    name = "sync-point"
    description = ("host-device sync constructs inside the engine's "
                   "run-execution hot paths (one-sync-per-run contract)")

    def applies_to(self, sf: SourceFile) -> bool:
        return is_engine_file(sf.rel)

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for fn in self._hot_functions(sf.tree):
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                msg = self._classify(call)
                if msg is None:
                    continue
                f = sf.finding(self.name, call,
                               f"{msg} inside hot path "
                               f"'{fn.name}' — the run boundary is the "
                               f"only allowed sync point")
                if f is not None:
                    findings.append(f)
        return findings

    # ------------------------------------------------------------------
    def _hot_functions(self, tree: ast.AST):
        """Every FunctionDef named in HOT_FUNCTIONS, wherever it nests
        (class methods and nested closures alike)."""
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in HOT_FUNCTIONS:
                yield node

    def _classify(self, call: ast.Call):
        func = call.func
        if isinstance(func, ast.Attribute):
            if func.attr in _SYNC_METHOD_CALLS:
                return f".{func.attr}() (device->host transfer)"
            dn = dotted_name(func)
            if dn in _SYNC_DOTTED:
                return f"{dn}() (blocking sync / host transfer)"
            if func.attr in ("block_until_ready", "device_get"):
                return f".{func.attr}() (blocking sync)"
        elif isinstance(func, ast.Name) and func.id in _SCALAR_COERCIONS:
            if call.args and not isinstance(
                    call.args[0], (ast.Constant, ast.Name)):
                return (f"{func.id}() scalar coercion of a non-trivial "
                        f"expression (blocks if the value is on device)")
        return None
