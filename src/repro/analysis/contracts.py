"""Checker 5 — ``backend-contract``: model-keyed signatures, no Executor.

PR 4 made the Backend contract model-keyed: every contract method takes
the registry model name as its first argument after ``self`` (``prepare
(model, req, ...)``, ``execute_run(model, sb, run)``, ...), so the
session can say WHOSE work each call is and ``MultiBackend`` can route.
A subclass that drifts off those signatures (renames/omits the key)
still "works" single-model and silently misroutes multi-tenant — this
checker catches the drift statically:

  * every class whose (textual) bases include ``Backend`` or
    ``MultiBackend`` must give each overridden contract method a first
    parameter named ``model``,
  * any class overriding EITHER of the per-request residency hooks
    (``reset_request`` — fault recovery drops the slot, ``release_
    request`` — the session forgets the request) must override BOTH:
    a backend tracking residency with only one of the pair leaks
    phantom slots on whichever path it ignores (exactly the
    ``SimExecutor`` gap this rule was added to close),
  * nothing in production code may import or reference the retired
    ``Executor`` alias (it resolves to ``Backend`` behind a
    DeprecationWarning for external callers only; the test tree is
    exempt — deprecation tests must poke the shim).
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile, is_test_file

#: per-request residency hooks: overriding one without the other leaves
#: a path (fault reset vs. handle release) that never frees the slot
RESIDENCY_PAIR = ("reset_request", "release_request")

#: Contract methods whose FIRST parameter after self is the model key.
MODEL_KEYED = {
    "prepare", "execute", "execute_run", "on_finished", "release_request",
    "token_count", "tokens", "memory_stats", "sanitizer_stats",
}
_BACKEND_BASES = {"Backend", "MultiBackend"}


def _base_names(cls: ast.ClassDef):
    for b in cls.bases:
        if isinstance(b, ast.Name):
            yield b.id
        elif isinstance(b, ast.Attribute):
            yield b.attr


class BackendContractChecker(Checker):
    name = "backend-contract"
    description = ("Backend subclasses drifting off the model-keyed "
                   "contract signatures; internal use of the retired "
                   "Executor alias")

    def applies_to(self, sf: SourceFile) -> bool:
        return True

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        findings.extend(self._check_signatures(sf))
        findings.extend(self._check_residency_pair(sf))
        if not is_test_file(sf.rel):
            findings.extend(self._check_executor_refs(sf))
        return findings

    # ------------------------------------------------------------------
    def _check_signatures(self, sf: SourceFile):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not (_BACKEND_BASES & set(_base_names(node))):
                continue
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name not in MODEL_KEYED:
                    continue
                args = item.args.posonlyargs + item.args.args
                first = args[1].arg if len(args) >= 2 else None
                if first != "model":
                    f = sf.finding(
                        self.name, item,
                        f"{node.name}.{item.name} first parameter is "
                        f"{first!r}, not 'model' — the Backend contract "
                        f"is model-keyed (MultiBackend routes on it)")
                    if f is not None:
                        yield f

    def _check_residency_pair(self, sf: SourceFile):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            defined = {item.name for item in node.body
                       if isinstance(item, (ast.FunctionDef,
                                            ast.AsyncFunctionDef))}
            have = defined & set(RESIDENCY_PAIR)
            if not have or have == set(RESIDENCY_PAIR):
                continue
            missing = (set(RESIDENCY_PAIR) - have).pop()
            present = have.pop()
            f = sf.finding(
                self.name, node,
                f"{node.name} overrides {present} but not {missing} — "
                f"a backend tracking per-request residency needs the "
                f"full reset/release pair, or the path through "
                f"{missing} strands its slot")
            if f is not None:
                yield f

    def _check_executor_refs(self, sf: SourceFile):
        for node in ast.walk(sf.tree):
            bad = None
            if isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name == "Executor":
                        bad = "import of"
            elif isinstance(node, ast.Attribute) and node.attr == "Executor":
                bad = "attribute reference to"
            elif isinstance(node, ast.Name) and node.id == "Executor" \
                    and isinstance(node.ctx, ast.Load):
                bad = "reference to"
            if bad is None:
                continue
            f = sf.finding(
                self.name, node,
                f"{bad} the retired 'Executor' alias — internal code "
                f"must use Backend (the alias exists only as a "
                f"deprecation shim for external callers)")
            if f is not None:
                yield f
