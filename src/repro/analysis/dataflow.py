"""Worklist fixpoint engine over per-checker abstract lattices.

An :class:`Analysis` supplies the lattice (``initial``/``join``) and the
semantics (``transfer``/``refine``/``may_raise``); :func:`analyze` runs
it to fixpoint over a :class:`~repro.analysis.cfg.CFG` and returns the
in-state of every node — including the synthetic ``exit`` and ``raise``
nodes, whose in-states are exactly "what can be true when the function
returns normally" and "what can be true when an exception escapes".

Abstract states are plain dicts (variable -> lattice value, compared
with ``==``); a variable absent from the dict is bottom. Every checker
lattice here is finite and ``join`` is monotone, so the worklist
terminates (loops converge in at most |lattice| passes).

Edge semantics:

  * ``normal`` out of a statement node: ``transfer(state, stmt)`` — the
    statement completed.
  * ``exc`` out of any node: the PRE state, i.e. the state *before* the
    statement ran — an exception means it may not have completed, which
    is the conservative direction for may-leak analyses. The edge is
    only propagated when ``may_raise(node)`` says so; analyses declare
    release/bookkeeping statements non-raising so the canonical
    pop → guard → append idiom does not flag its own epilogue.
  * ``true``/``false`` out of a branch node: ``transfer`` then
    ``refine(state, test, branch)`` — the hook where ``x is None`` /
    ``x is not None`` guards narrow a maybe-acquired token.
  * any edge out of a ``yield`` node (an ``await`` suspension point,
    see :mod:`cfg`): ``suspend(state, node)`` instead of ``transfer`` —
    the statement's own semantics were already applied at its ``stmt``
    node; the yield node models ONLY the interleaving window, where an
    async-aware analysis invalidates or checks whatever must not span a
    suspension. Default: identity (sync analyses are unaffected). Yield
    nodes may raise by construction (``CancelledError`` lands there).
"""
from __future__ import annotations

import ast
from collections import deque
from typing import Dict, Optional

from .cfg import CFG, EXC, FALSE, TRUE, Node

State = Dict[str, object]


class Analysis:
    """Abstract semantics of one dataflow checker. Subclass and override;
    the defaults are the identity analysis."""

    def initial(self) -> State:
        return {}

    def join(self, a: State, b: State) -> State:
        """Least upper bound of two states (may-analysis union)."""
        out = dict(a)
        for k, v in b.items():
            if k not in out:
                out[k] = v
            elif out[k] != v:
                out[k] = self.join_values(out[k], v)
        return out

    def join_values(self, a, b):
        """LUB of two lattice values for one variable."""
        return a

    def transfer(self, state: State, stmt: ast.AST) -> State:
        return state

    def refine(self, state: State, test: Optional[ast.AST],
               branch: bool) -> State:
        return state

    def suspend(self, state: State, node: Node) -> State:
        """State transform across a yield point (``await`` /
        ``async for`` step / ``async with`` enter-exit): other tasks
        may have run. Default: identity."""
        return state

    def may_raise(self, node: Node) -> bool:
        """Whether ``node``'s exception out-edge is live. Default: a
        branch test without calls cannot raise (``x is None``, bare
        names, attribute truthiness); everything else may."""
        if node.kind == "branch":
            return _has_call(node.test)
        if node.kind == "yield":
            return True             # awaits deliver CancelledError here
        if isinstance(node.stmt, ast.Raise):
            return True                          # structural, always
        return True


def _has_call(expr: Optional[ast.AST]) -> bool:
    if expr is None:
        return True                              # for-loop iteration step
    return any(isinstance(n, ast.Call) for n in ast.walk(expr))


def _post(analysis: Analysis, node: Node, in_s: State) -> State:
    """Post-state of ``node``: ``suspend`` at yield points (the stmt's
    semantics already ran at its own node), ``transfer`` elsewhere."""
    if node.kind == "yield":
        return analysis.suspend(in_s, node)
    if node.stmt is not None:
        return analysis.transfer(in_s, node.stmt)
    return in_s


def analyze(cfg: CFG, analysis: Analysis) -> Dict[int, State]:
    """Run ``analysis`` to fixpoint; returns {node-id: in-state}.
    Unreachable nodes have no entry."""
    in_states: Dict[int, State] = {cfg.entry.nid: analysis.initial()}
    worklist = deque([cfg.entry.nid])
    queued = {cfg.entry.nid}
    while worklist:
        nid = worklist.popleft()
        queued.discard(nid)
        node = cfg.nodes[nid]
        in_s = in_states[nid]
        post = None                              # lazily computed transfer
        for edge in cfg.succs[nid]:
            if edge.kind == EXC:
                if not (isinstance(node.stmt, ast.Raise)
                        or analysis.may_raise(node)):
                    continue
                out = in_s                       # pre-state, see module doc
            elif edge.kind in (TRUE, FALSE):
                if post is None:
                    post = _post(analysis, node, in_s)
                out = analysis.refine(post, node.test, edge.kind == TRUE)
            else:
                if post is None:
                    post = _post(analysis, node, in_s)
                out = post
            old = in_states.get(edge.dst)
            new = out if old is None else analysis.join(old, out)
            if old is None or new != old:
                in_states[edge.dst] = new
                if edge.dst not in queued:
                    worklist.append(edge.dst)
                    queued.add(edge.dst)
    return in_states
