"""Module-level call graph over the linted tree, as cacheable facts.

Interprocedural checkers (``wallclock-taint``) need to know who calls
whom across files. Exact Python call resolution is undecidable; this
graph resolves by *import neighborhood* instead of by global name —
coarse enough to over-approximate, tight enough that ``server.run()``
does not alias a benchmark's unrelated ``run()``:

  * a bare call ``foo()`` resolves to the caller file's own ``foo``,
    or to the symbol a ``from m import foo`` binding names,
  * a dotted call ``alias.foo()`` whose root is an imported module
    alias resolves into that module,
  * a dotted call with an unknown root (``self.foo()``, ``obj.foo()``)
    resolves to every def named ``foo`` in the caller's file or in any
    module the caller imports — the dynamic-dispatch neighborhood,
  * calls to a Backend-contract method (``execute_run``, ``prepare``,
    ...) are **polymorphic barrier sites**: the callee could be the
    analytic simulator or the JAX engine, and the contract itself is
    the sanctioned wall-time boundary (the session's virtual clock
    advances by whatever latency the backend returns — virtual in sim,
    measured in JAX). Taint never propagates through a barrier name.
  * test files are callers, never callees: production code cannot call
    into tests, and a test helper sharing a production name must not
    taint it by coincidence.

:class:`FileFacts` is a plain-dict round-trip (``to_dict``/
``from_dict``) so the ``--cache`` layer can persist facts per content
hash and interprocedural passes run without re-parsing unchanged files.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .base import SourceFile, dotted_name, is_test_file
from .contracts import MODEL_KEYED

#: Backend-contract method names: polymorphic call sites, taint barriers.
BARRIER_METHODS = frozenset(MODEL_KEYED) | frozenset({"reset_request"})

#: Wall-clock sources (the same set the old intraprocedural determinism
#: rule matched; recorded here as facts, judged by the taint checker).
WALL_CLOCK = frozenset({
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "time.clock",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
})

#: checker name whose suppressions gate clock facts (a suppressed read
#: is an audited boundary: it neither reports nor taints)
CHECKER = "wallclock-taint"

#: checker name whose suppressions gate blocking-call facts the same
#: way (an audited blocking site — the SessionDriver bridge — neither
#: reports nor propagates loop-blocking taint)
BLOCKING_CHECKER = "blocking-in-async"


class FuncFacts:
    """One function's interprocedural surface: who it calls, which
    clocks it reads, and its *effect summary* — whether it is async,
    whether it may suspend (contains an await / ``async for`` /
    ``async with`` of its own), and which shared attributes
    (``self.*``) it may read or write. The effect summary is what the
    async-aware checkers (:mod:`asyncrace`) reason over without
    re-parsing cached files."""

    __slots__ = ("qualname", "name", "lineno", "calls", "clock_reads",
                 "is_async", "suspends", "self_reads", "self_writes")

    def __init__(self, qualname: str, name: str, lineno: int,
                 is_async: bool = False):
        self.qualname = qualname
        self.name = name                 # bare (last) name
        self.lineno = lineno
        self.is_async = is_async
        self.suspends = False            # own await / async-for / -with
        # [{'name', 'dotted', 'line', 'snippet', 'suppressed',
        #   'awaited'}] — 'awaited' = the call is the direct operand of
        # an ``await`` (it cannot block the loop as a sync call would)
        self.calls: List[dict] = []
        # [{'dotted', 'line', 'snippet', 'suppressed'}]
        self.clock_reads: List[dict] = []
        # attr name -> first line it is read / written ({'attr','line'})
        self.self_reads: List[dict] = []
        self.self_writes: List[dict] = []

    def to_dict(self) -> dict:
        return {"qualname": self.qualname, "name": self.name,
                "lineno": self.lineno, "calls": self.calls,
                "clock_reads": self.clock_reads,
                "is_async": self.is_async, "suspends": self.suspends,
                "self_reads": self.self_reads,
                "self_writes": self.self_writes}

    @classmethod
    def from_dict(cls, d: dict) -> "FuncFacts":
        f = cls(d["qualname"], d["name"], d["lineno"],
                d.get("is_async", False))
        f.calls = d["calls"]
        f.clock_reads = d["clock_reads"]
        f.suspends = d.get("suspends", False)
        f.self_reads = d.get("self_reads", [])
        f.self_writes = d.get("self_writes", [])
        return f


class FileFacts:
    __slots__ = ("rel", "functions", "imports")

    def __init__(self, rel: str):
        self.rel = rel
        self.functions: Dict[str, FuncFacts] = {}
        # local alias -> dotted target ("srv" -> "repro.serving.server",
        # "run_policy" -> "repro.serving.server.run_policy")
        self.imports: Dict[str, str] = {}

    def to_dict(self) -> dict:
        return {"rel": self.rel, "imports": self.imports,
                "functions": {q: f.to_dict()
                              for q, f in self.functions.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "FileFacts":
        ff = cls(d["rel"])
        ff.imports = d.get("imports", {})
        ff.functions = {q: FuncFacts.from_dict(fd)
                        for q, fd in d["functions"].items()}
        return ff


def _package_of(rel: str) -> List[str]:
    """['repro', 'serving'] for 'repro/serving/session.py'."""
    parts = rel.split("/")
    return parts[:-1]


def _record_imports(sf: SourceFile, facts: FileFacts) -> None:
    pkg = _package_of(sf.rel)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                target = alias.name if alias.asname else \
                    alias.name.split(".")[0]
                facts.imports[local] = target
                # the full dotted module is reachable through the root
                if alias.asname is None and "." in alias.name:
                    facts.imports.setdefault(alias.name, alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:                       # relative: resolve
                base = pkg[:len(pkg) - (node.level - 1)] if node.level > 1 \
                    else pkg
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            for alias in node.names:
                if alias.name == "*":
                    continue
                local = alias.asname or alias.name
                facts.imports[local] = f"{mod}.{alias.name}" if mod \
                    else alias.name


def _note_attr(entries: List[dict], attr: str, line: int) -> None:
    """Record the FIRST line each attribute is touched (summary, not a
    site list — the per-file checkers see exact sites anyway)."""
    for e in entries:
        if e["attr"] == attr:
            return
    entries.append({"attr": attr, "line": line})


def extract_facts(sf: SourceFile) -> FileFacts:
    facts = FileFacts(sf.rel)
    _record_imports(sf, facts)

    def visit(body: Iterable[ast.AST], qual: List[str],
              fn: Optional[FuncFacts]):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = ".".join(qual + [node.name])
                sub = FuncFacts(q, node.name, node.lineno,
                                isinstance(node, ast.AsyncFunctionDef))
                facts.functions[q] = sub
                visit(node.body, qual + [node.name], sub)
            elif isinstance(node, ast.ClassDef):
                visit(node.body, qual + [node.name], fn)
            else:
                record(node, fn)

    def record(stmt: ast.AST, fn: Optional[FuncFacts]):
        if fn is None:
            fn = facts.functions.setdefault(
                "<module>", FuncFacts("<module>", "<module>", 1))
        awaited_calls = set()
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Await, ast.AsyncFor, ast.AsyncWith)):
                fn.suspends = True
                if isinstance(node, ast.Await) \
                        and isinstance(node.value, ast.Call):
                    awaited_calls.add(id(node.value))
            elif isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "self":
                if isinstance(node.ctx, ast.Load):
                    _note_attr(fn.self_reads, node.attr, node.lineno)
                else:                    # Store / Del / AugStore
                    _note_attr(fn.self_writes, node.attr, node.lineno)
            elif isinstance(node, ast.Subscript) \
                    and not isinstance(node.ctx, ast.Load) \
                    and isinstance(node.value, ast.Attribute) \
                    and isinstance(node.value.value, ast.Name) \
                    and node.value.value.id == "self":
                # self.x[k] = v / del self.x[k]: a WRITE of self.x
                _note_attr(fn.self_writes, node.value.attr, node.lineno)
        for call in ast.walk(stmt):
            if not isinstance(call, ast.Call):
                continue
            dn = dotted_name(call.func)
            if not dn:
                continue
            line = call.lineno
            suppressed = sf.suppressed(CHECKER, line)
            if dn in WALL_CLOCK:
                fn.clock_reads.append(
                    {"dotted": dn, "line": line,
                     "snippet": sf.line_at(line),
                     "suppressed": suppressed})
            else:
                fn.calls.append(
                    {"name": dn.rsplit(".", 1)[-1], "dotted": dn,
                     "line": line, "snippet": sf.line_at(line),
                     "suppressed": suppressed,
                     "suppressed_blocking": sf.suppressed(
                         BLOCKING_CHECKER, line),
                     "awaited": id(call) in awaited_calls})

    visit(sf.tree.body, [], None)
    return facts


class CallGraph:
    """Import-neighborhood call resolution over :class:`FileFacts`."""

    def __init__(self, all_facts: Dict[str, FileFacts]):
        self.files = all_facts
        # dotted module -> rel of the scanned file implementing it
        self.module_rel: Dict[str, str] = {}
        for rel in all_facts:
            if rel.endswith(".py"):
                dotted = rel[:-3].replace("/", ".")
                if dotted.endswith(".__init__"):
                    dotted = dotted[:-len(".__init__")]
                self.module_rel[dotted] = rel
        # (rel, bare name) -> [qualnames] of defs in that file
        self._defs: Dict[Tuple[str, str], List[str]] = {}
        for rel, ff in all_facts.items():
            for q, fn in ff.functions.items():
                self._defs.setdefault((rel, fn.name), []).append(q)
        # rel -> rels of the modules it imports (its neighborhood)
        self._neighbors: Dict[str, Set[str]] = {}
        for rel, ff in all_facts.items():
            hood: Set[str] = set()
            for target in ff.imports.values():
                r = self._module_file(target)
                if r is None and "." in target:   # from m import symbol
                    r = self._module_file(target.rsplit(".", 1)[0])
                if r is not None:
                    hood.add(r)
            self._neighbors[rel] = hood

    # ------------------------------------------------------------------
    def _module_file(self, dotted: str) -> Optional[str]:
        rel = self.module_rel.get(dotted)
        if rel is not None and not is_test_file(rel):
            return rel
        return None

    def _defs_in(self, rel: Optional[str], name: str) -> List[Tuple[str, str]]:
        if rel is None or is_test_file(rel):
            return []
        return [(rel, q) for q in self._defs.get((rel, name), ())]

    # ------------------------------------------------------------------
    def resolve(self, rel: str, call: dict) -> List[Tuple[str, str]]:
        """Possible (rel, qualname) callees of one recorded call."""
        name = call["name"]
        dotted = call.get("dotted", name)
        ff = self.files[rel]
        out: List[Tuple[str, str]] = []
        seen: Set[Tuple[str, str]] = set()

        def add(cands: Iterable[Tuple[str, str]]):
            for c in cands:
                if c not in seen:
                    seen.add(c)
                    out.append(c)

        def own_defs():
            # a file can always call its own functions — even a test
            # file (the cross-file exclusion lives in ``_defs_in``)
            return [(rel, q) for q in self._defs.get((rel, name), ())]

        if "." not in dotted:
            # bare call: this file's own def, plus the imported symbol
            add(own_defs())
            target = ff.imports.get(name)
            if target is not None and "." in target:
                mod, leaf = target.rsplit(".", 1)
                add(self._defs_in(self._module_file(mod), leaf))
            return out

        root = dotted.split(".", 1)[0]
        target = ff.imports.get(root)
        if target is not None:
            # alias.path.leaf -> module(alias.path) . leaf
            full = target + dotted[len(root):]
            mod, leaf = full.rsplit(".", 1)
            r = self._module_file(mod)
            if r is not None:
                add(self._defs_in(r, leaf))
                return out
            # `from m import Class` and the call is Class.method(...)
            r = self._module_file(target) or (
                self._module_file(target.rsplit(".", 1)[0])
                if "." in target else None)
            if r is not None:
                add(self._defs_in(r, name))
                return out
        # unknown receiver (self.foo(), obj.foo()): the dynamic-dispatch
        # neighborhood — this file and everything it imports
        add(own_defs())
        for nrel in sorted(self._neighbors.get(rel, ())):
            add(self._defs_in(nrel, name))
        return out
