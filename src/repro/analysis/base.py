"""Shared lint infrastructure: findings, suppressions, baselines, scoping.

A :class:`Finding` is one rule violation at one source location. Its
*fingerprint* is deliberately line-number-free — ``(checker, repo-relative
path, normalized source snippet, occurrence index)`` — so unrelated edits
above a legacy finding never churn the committed baseline, while a second
identical violation in the same file IS a new finding (the occurrence
index disambiguates).

Suppressions are source comments::

    risky_line()            # reprolint: disable=sync-point
    # reprolint: disable=bare-assert,determinism   (applies to next line)

A suppression names the checker(s) it silences (or ``all``); it applies
to the finding's own line or the line directly above (multi-line
expressions report the line their AST node starts on).

The baseline file (``reprolint.baseline.json`` at the repo root) pins the
legacy findings the lint run tolerates: findings whose fingerprint is in
the baseline are *baselined* (reported, never failing), anything else is
*new* (fails), and baseline entries no findings match anymore are *stale*
(the debt was paid — remove the entry).
"""
from __future__ import annotations

import ast
import hashlib
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([\w,\s-]+)")


def rel_path(path) -> str:
    """Repo-stable identity for ``path``: the posix path from its last
    ``repro/`` package component down (``repro/serving/engine.py``), so
    fingerprints agree no matter where the tree is checked out or which
    directory the lint runs from. ``tests/`` and ``benchmarks/`` trees
    (now also linted) get the same treatment — ``tests/test_foo.py``,
    ``benchmarks/fig12_latency.py``. Anything else falls back to its
    posix form as given."""
    p = Path(path).as_posix()
    for root in ("repro", "tests", "benchmarks"):
        marker = f"/{root}/"
        i = p.rfind(marker)
        if i >= 0:
            return root + "/" + p[i + len(marker):]
        if p.startswith(root + "/"):
            return p
    return p


@dataclass
class Finding:
    checker: str
    path: str                # repo-stable rel path (see rel_path)
    line: int
    message: str
    snippet: str = ""        # the offending source line, stripped
    occurrence: int = 0      # index among same-(checker, path, snippet)
    file: str = ""           # real on-disk path (CI annotations only;
    #                          NOT part of the fingerprint)

    @property
    def fingerprint(self) -> str:
        key = "|".join((self.checker, self.path,
                        " ".join(self.snippet.split()),
                        str(self.occurrence)))
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def __str__(self):
        return (f"{self.path}:{self.line}: [{self.checker}] {self.message}"
                + (f"\n    {self.snippet}" if self.snippet else ""))


class SourceFile:
    """One parsed source file handed to every checker: raw text, line
    list, AST, and the per-line suppression table."""

    def __init__(self, path, text: Optional[str] = None):
        self.path = Path(path)
        self.rel = rel_path(path)
        self.text = self.path.read_text() if text is None else text
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        self.suppressions: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                names = {n.strip() for n in m.group(1).split(",") if n.strip()}
                self.suppressions[lineno] = names

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def suppressed(self, checker: str, lineno: int) -> bool:
        for ln in (lineno, lineno - 1):
            names = self.suppressions.get(ln)
            if names and (checker in names or "all" in names):
                return True
        return False

    def finding(self, checker: str, node: ast.AST, message: str):
        """Build a Finding for ``node`` unless a suppression covers it."""
        lineno = getattr(node, "lineno", 1)
        if self.suppressed(checker, lineno):
            return None
        return Finding(checker=checker, path=self.rel, line=lineno,
                       message=message, snippet=self.line_at(lineno),
                       file=str(self.path))


class Checker:
    """One lint rule family. Subclasses set ``name`` and implement
    :meth:`check`; :meth:`applies_to` scopes which files are visited."""

    name = "abstract"
    description = ""

    def applies_to(self, sf: SourceFile) -> bool:
        return True

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        raise NotImplementedError


class ProjectChecker:
    """A whole-project (interprocedural) rule family: runs ONCE over the
    per-file facts of every linted file (see :mod:`callgraph`) instead
    of per file — which is also what lets the ``--cache`` layer skip
    re-parsing unchanged files while interprocedural checks still see
    the whole tree."""

    name = "abstract-project"
    description = ""

    def check_project(self, facts: Dict[str, object],
                      graph) -> Iterable[Finding]:
        raise NotImplementedError


def assign_occurrences(findings: List[Finding]) -> List[Finding]:
    """Stamp each finding's occurrence index among its same-snippet twins
    (in (path, line) order) so fingerprints are unique and stable."""
    findings = sorted(findings, key=lambda f: (f.path, f.line, f.checker))
    seen: Dict[tuple, int] = {}
    for f in findings:
        key = (f.checker, f.path, " ".join(f.snippet.split()))
        f.occurrence = seen.get(key, 0)
        seen[key] = f.occurrence + 1
    return findings


@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)
    new: List[Finding] = field(default_factory=list)         # fail CI
    baselined: List[Finding] = field(default_factory=list)   # legacy debt
    stale: List[dict] = field(default_factory=list)          # paid-off debt

    @property
    def ok(self) -> bool:
        return not self.new


def split_against_baseline(findings: List[Finding],
                           baseline: List[dict]) -> LintResult:
    res = LintResult(findings=findings)
    known = {e["fingerprint"]: e for e in baseline}
    matched: Set[str] = set()
    for f in findings:
        fp = f.fingerprint
        if fp in known:
            matched.add(fp)
            res.baselined.append(f)
        else:
            res.new.append(f)
    res.stale = [e for e in baseline if e["fingerprint"] not in matched]
    return res


def load_baseline(path) -> List[dict]:
    doc = json.loads(Path(path).read_text())
    return doc.get("findings", [])


def write_baseline(path, findings: List[Finding]) -> None:
    doc = {
        "comment": ("reprolint legacy-finding baseline: every entry is "
                    "known debt to burn down, NOT an allowance for new "
                    "code. Remove entries as they are fixed; never add "
                    "one without a review saying why it cannot be fixed "
                    "now."),
        "findings": [{"fingerprint": f.fingerprint, "checker": f.checker,
                      "path": f.path, "line": f.line,
                      "message": f.message, "snippet": f.snippet}
                     for f in findings],
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


# ---------------------------------------------------------------------------
# Scope predicates shared by checkers
# ---------------------------------------------------------------------------

def is_engine_file(rel: str) -> bool:
    """The run-execution hot path lives here (sync/retrace checkers)."""
    return rel.endswith("repro/serving/engine.py") \
        or rel == "repro/serving/engine.py"


def is_test_file(rel: str) -> bool:
    """Pytest tree: asserts are the idiom there (bare-assert exempt),
    and tests deliberately poke deprecated shims (Executor-alias rule
    exempt)."""
    return rel.startswith("tests/") or "/tests/" in rel


def is_benchmark_file(rel: str) -> bool:
    return rel.startswith("benchmarks/") or "/benchmarks/" in rel


#: Modules whose notion of time is VIRTUAL (the discrete-event clock) or
#: that feed it: wall-clock reads and unseeded RNG here silently break
#: replay determinism and sim/JAX parity. ``launch/roofline.py`` and
#: ``launch/dryrun.py`` are included by audit decision — their wall-clock
#: probe timings are legitimate but must stay annotated so a new one is a
#: conscious choice.
VIRTUAL_TIME_SUFFIXES = (
    "repro/serving/server.py",
    "repro/serving/session.py",
    "repro/serving/metrics.py",
    "repro/serving/traffic.py",
    "repro/serving/workload.py",
    "repro/serving/registry.py",
    "repro/serving/backend.py",
    "repro/launch/roofline.py",
    "repro/launch/dryrun.py",
)


#: Audited wall-clock boundaries: modules whose *job* is to touch the
#: wall clock, reviewed as a unit rather than via per-line suppressions.
#: The serving gateway is the canonical case — it paces the virtual-time
#: session against real time (``SessionDriver``: ``target = (loop.time()
#: - t0) * time_scale``), serves SSE to real sockets, and enforces
#: wall-clock request timeouts. Per-line ``# reprolint:`` pragmas on
#: every ``loop.time()`` there would be pure noise and would train
#: readers to ignore suppressions; declaring the prefix keeps the audit
#: meaningful where it matters (the sim/replay path stays strict: a
#: clock read in ``serving/session.py`` et al. still fires, and taint
#: still propagates out of any NON-audited module into virtual-time
#: code). Adding a prefix here is a reviewed audit decision — the
#: boundary module must keep wall time out of SLA/latency arithmetic,
#: as ``gateway/bridge.py``'s module docstring spells out.
WALLCLOCK_AUDITED_PREFIXES = (
    "repro/serving/gateway/",
)


def is_wallclock_audited(rel: str) -> bool:
    """True when ``rel`` lies inside a declared, audited wall-clock
    boundary (see :data:`WALLCLOCK_AUDITED_PREFIXES`)."""
    return rel.startswith(WALLCLOCK_AUDITED_PREFIXES)


def is_virtual_time_file(rel: str) -> bool:
    if "repro/core/" in rel:
        return True
    # paper-figure benchmarks drive the virtual-time simulator: their
    # reported latencies/SLAs must come from the event clock too
    if is_benchmark_file(rel) and Path(rel).name.startswith("fig"):
        return True
    return any(rel.endswith(sfx) for sfx in VIRTUAL_TIME_SUFFIXES)


# ---------------------------------------------------------------------------
# Small AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str:
    """'np.random.default_rng' for nested Attribute/Name chains, '' when
    the expression is not a plain dotted chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def walk_calls(tree: ast.AST) -> Iterable[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node
