"""swallowed-exception: error paths must not eat faults or leak slots.

The failure model (serving.session + serving.faults) turns backend
faults into *accounted* outcomes — retries, terminal FAILED states,
released KV slots. That only works if no layer underneath silently
swallows the exception first, and if no acquire-then-raise window can
strand a slot. Two rule families:

**A — swallowed exceptions (repo-wide).** A bare ``except:`` (catches
``KeyboardInterrupt``/``SystemExit`` too) whose handler does not
re-raise, and any ``except Exception/BaseException`` handler whose
entire body is ``pass``/``...`` — the canonical fault black hole: a
``BackendError`` raised under it simply vanishes, the session never
sees the fault, and the dispatched run's requests hang forever.

**B — slot-leaking try bodies (serving modules).** A ``try`` whose body
can ACQUIRE per-request device residency (``slot_of`` / ``_touch`` /
``_grow_arena`` / ``prepare``) but has no ``finally`` and whose
handlers neither re-raise nor call a RELEASE hook (``release_slot`` /
``_release_slots`` / ``release_request`` / ``reset_request`` /
``on_finished``): if the body raises after the acquire, the slot never
returns to the free pool — exactly the leak class the
``memory_stats()``-based zero-leak gates exist to catch at runtime;
this checker catches it at review time.

Legitimate record-don't-crash handlers (launch-time probes) carry a
reviewed ``# reprolint: disable=swallowed-exception`` suppression.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile, dotted_name, walk_calls

#: calls that take per-request device residency (a KV slot) ...
ACQUIRE_CALLS = frozenset({"slot_of", "_touch", "_grow_arena", "prepare"})
#: ... and the hooks that give it back (any one on the handler path
#: makes the try fault-safe; so does re-raising to a fault-aware caller)
RELEASE_CALLS = frozenset({"release_slot", "_release_slots",
                           "release_request", "reset_request",
                           "on_finished"})


def _is_serving_file(rel: str) -> bool:
    return "repro/serving/" in rel


def _handler_reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def _trivial_body(body: List[ast.stmt]) -> bool:
    """True when the handler body does nothing: only ``pass`` and/or
    bare ``...`` expressions."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and stmt.value.value is Ellipsis):
            continue
        return False
    return True


def _call_names(nodes: Iterable[ast.stmt]) -> set:
    names = set()
    for stmt in nodes:
        for call in walk_calls(stmt):
            dn = dotted_name(call.func)
            if dn:
                names.add(dn.rsplit(".", 1)[-1])
    return names


class SwallowedExceptionChecker(Checker):
    name = "swallowed-exception"
    description = ("bare/trivial exception handlers that eat backend "
                   "faults, and serving try bodies that can strand an "
                   "acquired KV slot without a finally/handler release")

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        serving = _is_serving_file(sf.rel)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Try):
                continue
            findings.extend(self._check_handlers(sf, node))
            if serving:
                findings.extend(self._check_slot_leak(sf, node))
        return [f for f in findings if f is not None]

    # -- rule A ---------------------------------------------------------
    def _check_handlers(self, sf: SourceFile, node: ast.Try):
        for handler in node.handlers:
            if handler.type is None:
                if not _handler_reraises(handler):
                    yield sf.finding(
                        self.name, handler,
                        "bare 'except:' swallows every exception "
                        "(KeyboardInterrupt and backend faults alike) — "
                        "catch the specific error, or re-raise")
                continue
            broad = dotted_name(handler.type) in ("Exception",
                                                  "BaseException")
            if broad and _trivial_body(handler.body):
                yield sf.finding(
                    self.name, handler,
                    "'except Exception: pass' is a fault black hole — a "
                    "BackendError dying here leaves its requests hanging "
                    "forever; handle it, record it, or let it propagate")

    # -- rule B ---------------------------------------------------------
    def _check_slot_leak(self, sf: SourceFile, node: ast.Try):
        if node.finalbody:
            return                       # finally runs on every path
        if not node.handlers:
            return                       # try/finally already handled
        acquired = _call_names(node.body) & ACQUIRE_CALLS
        if not acquired:
            return
        for handler in node.handlers:
            if _handler_reraises(handler):
                continue
            if _call_names(handler.body) & RELEASE_CALLS:
                continue
            yield sf.finding(
                self.name, handler,
                f"try body acquires per-request residency "
                f"({', '.join(sorted(acquired))}) but this handler "
                f"neither re-raises nor releases it (no finally either) "
                f"— an exception after the acquire leaks the KV slot")
