"""Checker 6 — ``swallowed-exception``: error paths must not eat faults.

The failure model (serving.session + serving.faults) turns backend
faults into *accounted* outcomes — retries, terminal FAILED states,
released KV slots. That only works if no layer underneath silently
swallows the exception first. Repo-wide rule:

A bare ``except:`` (catches ``KeyboardInterrupt``/``SystemExit`` too)
whose handler does not re-raise, and any ``except Exception/
BaseException`` handler whose entire body is ``pass``/``...`` — the
canonical fault black hole: a ``BackendError`` raised under it simply
vanishes, the session never sees the fault, and the dispatched run's
requests hang forever.

This checker used to carry a second, serving-scoped rule family
(syntactic slot-leaking-``try`` detection). That rule is retired: the
``slot-leak`` checker (:mod:`slotleak`) now proves the same property —
and the strictly larger class of leaks NOT framed by a ``try`` — with
real path-sensitive dataflow over the CFG, so this module is back to
exactly one job.

Legitimate record-don't-crash handlers (launch-time probes) carry a
reviewed ``# reprolint: disable=swallowed-exception`` suppression.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile, dotted_name


def _handler_reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def _trivial_body(body: List[ast.stmt]) -> bool:
    """True when the handler body does nothing: only ``pass`` and/or
    bare ``...`` expressions."""
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and stmt.value.value is Ellipsis):
            continue
        return False
    return True


class SwallowedExceptionChecker(Checker):
    name = "swallowed-exception"
    description = ("bare/trivial exception handlers that eat backend "
                   "faults (slot leaks: see slot-leak)")

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Try):
                continue
            findings.extend(self._check_handlers(sf, node))
        return [f for f in findings if f is not None]

    # ------------------------------------------------------------------
    def _check_handlers(self, sf: SourceFile, node: ast.Try):
        for handler in node.handlers:
            if handler.type is None:
                if not _handler_reraises(handler):
                    yield sf.finding(
                        self.name, handler,
                        "bare 'except:' swallows every exception "
                        "(KeyboardInterrupt and backend faults alike) — "
                        "catch the specific error, or re-raise")
                continue
            broad = dotted_name(handler.type) in ("Exception",
                                                  "BaseException")
            if broad and _trivial_body(handler.body):
                yield sf.finding(
                    self.name, handler,
                    "'except Exception: pass' is a fault black hole — a "
                    "BackendError dying here leaves its requests hanging "
                    "forever; handle it, record it, or let it propagate")
