"""Checker 7 — ``slot-leak``: path-sensitive KV-slot escape analysis.

The PR 7 bug class: a request's arena slot is popped from the free pool,
then an exception (OOM mid-grow, a fault injected between dispatch and
epilogue) skips the release path and the slot is stranded — the pool
shrinks by one forever, and only the runtime ``memory_stats()`` zero-leak
gates notice, long after review. The old syntactic rule (swallowed-
exception rule B) could only pattern-match ``try`` bodies; this checker
supersedes it with real dataflow over the :mod:`cfg` graphs: any path —
normal return OR escaping exception — on which an acquired slot leaves
the function neither released nor handed to a tracked owner is reported.

Abstract semantics (per function, lattice SAFE < MAYBE < ACQUIRED):

  * **acquire** — ``x = <pool>.popleft()`` / ``<pool>.pop()`` where the
    receiver names the free pool (``free_slots``) puts ``x`` in
    ACQUIRED; ``x = <owners>.pop(key, default)`` on a slot-owner map
    (receiver naming ``_slot``) puts ``x`` in MAYBE (the key may have
    been absent) — an ``x is (not) None`` guard refines MAYBE to SAFE /
    ACQUIRED on the respective branches, which is exactly the
    ``_release_slots`` idiom.
  * **release** — appending/extending the free pool with ``x``, or
    passing ``x`` to any call (a release hook like ``release_slot`` /
    ``_release_slots`` / ``on_finished``, or any callee — ownership
    escapes to it), moves ``x`` to SAFE.
  * **own** — storing ``x`` into an attribute/subscript (``self._slot
    [rid] = x``) or returning it transfers ownership out of the
    function: SAFE.
  * Release statements and plain ownership stores are treated as
    **non-raising** (their exception edges are dead): a free-pool
    ``append`` or a dict store raising would otherwise make the
    canonical acquire→own and pop→guard→append idioms flag their own
    epilogues.

Reported at the acquire site (stable fingerprint), naming the escaping
exit(s). Scope: ``repro/serving/`` — the only tree that owns device
residency.
"""
from __future__ import annotations

import ast
from types import SimpleNamespace
from typing import Dict, Iterable, List, Optional, Tuple

from .base import Checker, Finding, SourceFile
from .cfg import build_cfg, functions
from .dataflow import Analysis, analyze

#: receiver-name fragments identifying the free pool / the owner map
POOL_MARK = "free_slots"
OWNER_MARK = "_slot"

#: callee leaf names that give residency back (their call statements are
#: additionally treated as non-raising — they ARE the cleanup path)
RELEASE_CALLS = frozenset({"release_slot", "_release_slots",
                           "release_request", "reset_request",
                           "on_finished"})

ACQ, MAYBE = "acquired", "maybe"


def _recv_text(call: ast.Call) -> str:
    if isinstance(call.func, ast.Attribute):
        try:
            return ast.unparse(call.func.value)
        except Exception:
            return ""
    return ""


def _classify_acquire(value: ast.AST) -> Optional[str]:
    """ACQ/MAYBE/None for the RHS of an assignment."""
    if not (isinstance(value, ast.Call)
            and isinstance(value.func, ast.Attribute)):
        return None
    attr, recv = value.func.attr, _recv_text(value)
    if POOL_MARK in recv and attr in ("popleft", "pop"):
        return ACQ
    if OWNER_MARK in recv and attr == "pop" and value.args:
        return MAYBE if len(value.args) >= 2 else ACQ
    return None


def _is_release_stmt(stmt: ast.AST) -> bool:
    """Free-pool append/extend or a call to a release hook."""
    for call in ast.walk(stmt):
        if not isinstance(call, ast.Call):
            continue
        if isinstance(call.func, ast.Attribute):
            if call.func.attr in ("append", "appendleft", "extend") \
                    and POOL_MARK in _recv_text(call):
                return True
            if call.func.attr in RELEASE_CALLS:
                return True
        elif isinstance(call.func, ast.Name) \
                and call.func.id in RELEASE_CALLS:
            return True
    return False


def _is_simple(expr: ast.AST) -> bool:
    if isinstance(expr, (ast.Constant, ast.Name)):
        return True
    if isinstance(expr, (ast.Tuple, ast.List)):
        return all(_is_simple(e) for e in expr.elts)
    return False


def _is_owner_store(stmt: ast.AST) -> bool:
    """``obj.attr = x`` / ``obj[...] = x`` with a simple RHS: ownership
    moves into a container that outlives the function."""
    if not isinstance(stmt, ast.Assign) or not stmt.targets:
        return False
    return all(isinstance(t, (ast.Attribute, ast.Subscript))
               for t in stmt.targets) and _is_simple(stmt.value)


def _none_test(test: Optional[ast.AST]):
    """('x', True) for ``x is None``, ('x', False) for ``x is not None``,
    else None."""
    if (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.left, ast.Name)
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None):
        if isinstance(test.ops[0], ast.Is):
            return test.left.id, True
        if isinstance(test.ops[0], ast.IsNot):
            return test.left.id, False
    return None


class SlotAnalysis(Analysis):
    """var -> (ACQ|MAYBE, acquire-line); absent = SAFE."""

    def join_values(self, a: Tuple[str, int], b: Tuple[str, int]):
        # may-leak: ACQ wins over MAYBE; keep the acquiring side's line
        if a[0] == ACQ and b[0] != ACQ:
            return a
        if b[0] == ACQ and a[0] != ACQ:
            return b
        return min(a, b, key=lambda v: v[1])

    # ------------------------------------------------------------------
    def transfer(self, state, stmt):
        out = dict(state)
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            x = stmt.targets[0].id
            tag = _classify_acquire(stmt.value)
            if tag is not None:
                out[x] = (tag, stmt.lineno)
                return out
            if isinstance(stmt.value, ast.Name) \
                    and stmt.value.id in out:       # alias: move semantics
                out[x] = out.pop(stmt.value.id)
                return out
            out.pop(x, None)                        # strong update: killed
            self._escape_calls(stmt, out)
            return out
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                for n in ast.walk(stmt.value):
                    if isinstance(n, ast.Name):
                        out.pop(n.id, None)         # caller takes ownership
            return out
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    out.pop(t.id, None)
            return out
        if _is_owner_store(stmt):
            for n in ast.walk(stmt.value):
                if isinstance(n, ast.Name):
                    out.pop(n.id, None)
            return out
        self._escape_calls(stmt, out)
        return out

    @staticmethod
    def _escape_calls(stmt, out: Dict):
        """A tracked var passed to ANY call escapes to the callee
        (release hooks included — this is what makes them releases)."""
        for call in ast.walk(stmt):
            if not isinstance(call, ast.Call):
                continue
            args = list(call.args) + [kw.value for kw in call.keywords]
            for a in args:
                for n in ast.walk(a):
                    if isinstance(n, ast.Name):
                        out.pop(n.id, None)

    # ------------------------------------------------------------------
    def refine(self, state, test, branch: bool):
        nt = _none_test(test)
        if nt is None:
            return state
        var, is_none_branch = nt
        hit = state.get(var)
        if hit is None or hit[0] != MAYBE:
            return state
        out = dict(state)
        if branch == is_none_branch:
            out.pop(var)                    # it's None: nothing acquired
        else:
            out[var] = (ACQ, hit[1])        # definitely holding a slot
        return out

    # ------------------------------------------------------------------
    def may_raise(self, node) -> bool:
        stmt = node.stmt
        if node.kind == "branch":
            return super().may_raise(node)
        if stmt is None:
            return True
        if _is_release_stmt(stmt) or _is_owner_store(stmt):
            return False
        if isinstance(stmt, ast.Assign) and _is_simple(stmt.value) \
                and all(isinstance(t, ast.Name) for t in stmt.targets):
            return False
        if isinstance(stmt, ast.Return):
            return stmt.value is not None and any(
                isinstance(n, ast.Call) for n in ast.walk(stmt.value))
        return True


class SlotLeakChecker(Checker):
    name = "slot-leak"
    description = ("CFG paths (incl. exception edges) on which an "
                   "acquired KV slot escapes neither released nor "
                   "owned (supersedes the syntactic rule for serving)")

    def applies_to(self, sf: SourceFile) -> bool:
        return "repro/serving/" in sf.rel

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for func in functions(sf.tree):
            findings.extend(self._check_func(sf, func))
        return findings

    def _check_func(self, sf: SourceFile, func):
        cfg = build_cfg(func)
        states = analyze(cfg, SlotAnalysis())
        # (var, line) -> exits it escapes through
        leaks: Dict[Tuple[str, int], List[str]] = {}
        for exit_node, how in ((cfg.exit, "a normal return"),
                               (cfg.raise_exit, "an escaping exception")):
            for var, (tag, line) in states.get(exit_node.nid, {}).items():
                leaks.setdefault((var, line), []).append(how)
        for (var, line), hows in sorted(leaks.items(),
                                        key=lambda kv: kv[0][1]):
            f = sf.finding(
                self.name, SimpleNamespace(lineno=line),
                f"KV slot held in {var!r} can leave {func.name}() via "
                f"{' and via '.join(hows)} without being released to "
                f"the free pool or stored to a slot owner — the arena "
                f"strands one slot on that path")
            if f is not None:
                yield f
        return
