"""Checkers 10-12 — async-aware dataflow for the serving gateway.

The PR 9 gateway is a single-threaded asyncio application, which kills
data races between *instructions* but not between *awaits*: every yield
point is a window where another task may run, so shared gateway state
(backpressure counters, the SessionDriver backlog, metrics windows) can
be torn across a suspension, the event loop can be stalled by a sync
``session.run_until``, and a dropped task handle leaks work past drain.
Three checkers close those holes on top of the yield-point CFGs
(:mod:`cfg`), the suspension-aware fixpoint engine (:mod:`dataflow`)
and the per-function effect summaries (:mod:`callgraph`):

``await-atomicity`` (per-file, CFG lattice)
    Read-check-write of shared mutable state — ``self.*`` attributes
    and ``global``-declared names — spanning a yield point inside an
    ``async def``. The classic lost update::

        v = self.completed          # read
        await something()           # other tasks run HERE
        self.completed = v + 1      # write of a stale value

    A span is sanctioned two ways: **lock-set** — the yield point sits
    inside a ``with``/``async with`` whose context manager names a lock
    (``async with self._lock:``), so same-lock tasks cannot interleave
    — or **single-writer ownership** — the attribute is declared
    pump-task-only with the annotation vocabulary::

        self.completed = 0          # reprolint: owner=pump

    ``# reprolint: owner=<task>`` on an attribute's initialising
    assignment declares every write of that attribute file-wide to be
    the named task's alone (reviewed, like a suppression — the comment
    must say WHY single-writer holds). Findings report at the write
    with the full witness span (read line, await line, write line).

``blocking-in-async`` (project-wide, witness chains)
    Sync calls that stall the event loop — ``session.run_until`` /
    ``.step`` / ``.drain``, ``time.sleep``, ``subprocess.*``,
    ``loop.run_until_complete`` — reachable from an ``async def``
    through any chain of sync calls (or awaited async calls: awaiting a
    coroutine that blocks inside still stalls the loop). Propagation
    mirrors ``wallclock-taint``: blocking primitives seed taint, taint
    flows up the call graph (Backend-contract names stay barriers, a
    call to an UN-awaited async def spawns nothing and propagates
    nothing), and findings at the async frontier carry the witness
    chain down to the primitive. The sanctioned SessionDriver bridge
    sites (the pump tick's bounded ``run_until`` catch-up and the drain
    fast-forward) carry audited ``# reprolint:
    disable=blocking-in-async`` suppressions at the seed, so every
    caller of the audited bridge is sanctioned transitively.

``task-leak`` (per-file, syntactic + use analysis)
    Fire-and-forget asyncio: a ``create_task``/``ensure_future`` result
    dropped on the floor (bare expression statement) or bound to a name
    that is never used again — nothing awaits, cancels, tracks or
    reaps it, so drain cannot find it and its exceptions vanish; a
    coroutine function called but never awaited (the call builds a
    coroutine object and discards it — the body never runs); and
    ``except (asyncio.)CancelledError`` that swallows without a
    ``raise``, which strands ``drain()``'s cancellation sweep. The one
    sanctioned swallow is the *reap* idiom — a function that itself
    ``.cancel()``-ed the task may absorb the resulting
    ``CancelledError`` when awaiting it out.
"""
from __future__ import annotations

import ast
import re
from types import SimpleNamespace
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .base import (Checker, Finding, ProjectChecker, SourceFile,
                   dotted_name, is_benchmark_file, is_test_file)
from .callgraph import BARRIER_METHODS as _BARRIERS
from .callgraph import CallGraph, FileFacts
from .cfg import build_cfg, contains_await
from .dataflow import Analysis, analyze

#: ``# reprolint: owner=<task>`` — single-writer ownership annotation.
_OWNER_RE = re.compile(r"#\s*reprolint:\s*owner=([\w-]+)")
_SELF_ATTR_RE = re.compile(r"self\.(\w+)")

READ, STALE = "read", "stale"


def _in_scope(rel: str) -> bool:
    """Production sources only: tests drive event loops synchronously
    on purpose, and benchmarks (the load generator's spawn harness)
    block on subprocesses by design."""
    return "repro/" in rel and not is_test_file(rel) \
        and not is_benchmark_file(rel)


# ---------------------------------------------------------------------------
# shared-state access extraction
# ---------------------------------------------------------------------------

def owner_annotations(sf: SourceFile) -> Dict[str, str]:
    """attr name -> owning task, from ``self.X = ...  # reprolint:
    owner=<task>`` lines anywhere in the file."""
    owners: Dict[str, str] = {}
    for line in sf.lines:
        m = _OWNER_RE.search(line)
        if m is None:
            continue
        attr = _SELF_ATTR_RE.search(line)
        if attr is not None:
            owners[attr.group(1)] = m.group(1)
    return owners


def _global_names(func: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Global):
            names.update(node.names)
    return names


def _own_parts(stmt: ast.AST) -> List[ast.AST]:
    """The AST fragments that execute AT ``stmt``'s own CFG node. CFG
    branch/anchor nodes carry the whole compound statement in ``stmt``
    (``If``, ``While``, ``Try``, ...) but only the header runs there —
    the body has its own nodes, so walking it here would double-count."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.target, stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        parts: List[ast.AST] = []
        for item in stmt.items:
            parts.append(item.context_expr)
            if item.optional_vars is not None:
                parts.append(item.optional_vars)
        return parts
    if isinstance(stmt, (ast.Try, ast.ExceptHandler, ast.FunctionDef,
                         ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    return [stmt]


def _accesses(stmt: ast.AST, globals_: Set[str]
              ) -> Tuple[Set[str], Set[str]]:
    """(reads, writes) of shared keys at one CFG node. Keys are
    ``self.<attr>`` dotted paths and ``global``-declared bare names."""
    reads: Set[str] = set()
    writes: Set[str] = set()
    for part in _own_parts(stmt):
        for node in ast.walk(part):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "self":
                key = f"self.{node.attr}"
                if isinstance(node.ctx, ast.Load):
                    reads.add(key)
                else:
                    writes.add(key)
            elif isinstance(node, ast.Subscript) \
                    and not isinstance(node.ctx, ast.Load) \
                    and isinstance(node.value, ast.Attribute) \
                    and isinstance(node.value.value, ast.Name) \
                    and node.value.value.id == "self":
                writes.add(f"self.{node.value.attr}")
            elif isinstance(node, ast.Name) and node.id in globals_:
                (reads if isinstance(node.ctx, ast.Load)
                 else writes).add(node.id)
    # an AugAssign target parses as Store only; it reads too
    if isinstance(stmt, ast.AugAssign):
        t = stmt.target
        if isinstance(t, ast.Attribute) \
                and isinstance(t.value, ast.Name) \
                and t.value.id == "self":
            reads.add(f"self.{t.attr}")
        elif isinstance(t, ast.Name) and t.id in globals_:
            reads.add(t.id)
    return reads, writes


def _lock_ranges(func: ast.AST) -> List[Tuple[int, int]]:
    """Line spans of ``with``/``async with`` blocks whose context
    manager names a lock (heuristic: the item expression mentions
    "lock" / "sem", case-insensitive — ``self._lock``,
    ``asyncio.Lock()``, a semaphore)."""
    spans: List[Tuple[int, int]] = []
    for node in ast.walk(func):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        for item in node.items:
            try:
                text = ast.unparse(item.context_expr).lower()
            except Exception:
                continue
            if "lock" in text or "sem" in text:
                spans.append((node.lineno,
                              node.end_lineno or node.lineno))
                break
    return spans


# ---------------------------------------------------------------------------
# checker 10 — await-atomicity
# ---------------------------------------------------------------------------

class AtomicityAnalysis(Analysis):
    """key -> (READ, read-line) | (STALE, read-line, yield-line);
    absent = untracked. A read marks the key live; a yield point turns
    live reads stale (unless it sits inside a lock region); a write
    clears the key — checked against stale-ness in the post-pass."""

    def __init__(self, globals_: Set[str],
                 lock_spans: List[Tuple[int, int]]):
        self.globals_ = globals_
        self.lock_spans = lock_spans

    def join_values(self, a, b):
        # may-analysis: a possibly-stale read wins over a fresh one
        if a[0] == STALE and b[0] != STALE:
            return a
        if b[0] == STALE and a[0] != STALE:
            return b
        return min(a, b)

    def transfer(self, state, stmt):
        reads, writes = _accesses(stmt, self.globals_)
        out = dict(state)
        for key in writes:
            out.pop(key, None)          # the write resolves the span
        for key in reads:
            out[key] = (READ, stmt.lineno)
        return out

    def suspend(self, state, node):
        line = getattr(node.stmt, "lineno", 0)
        if any(lo <= line <= hi for lo, hi in self.lock_spans):
            return state                # suspended holding the lock
        out = {}
        for key, v in state.items():
            out[key] = (STALE, v[1], line) if v[0] == READ else v
        return out


class AwaitAtomicityChecker(Checker):
    name = "await-atomicity"
    description = ("read-check-write of shared state (self.* / globals) "
                   "spanning an await with no lock held and no "
                   "single-writer owner annotation")

    def applies_to(self, sf: SourceFile) -> bool:
        return _in_scope(sf.rel)

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        owners = owner_annotations(sf)
        findings: List[Finding] = []
        for func in ast.walk(sf.tree):
            if isinstance(func, ast.AsyncFunctionDef):
                findings.extend(self._check_func(sf, func, owners))
        return findings

    def _sanctioned(self, key: str, owners: Dict[str, str]) -> bool:
        return key.startswith("self.") and key[len("self."):] in owners

    def _check_func(self, sf: SourceFile, func, owners):
        globals_ = _global_names(func)
        lock_spans = _lock_ranges(func)
        analysis = AtomicityAnalysis(globals_, lock_spans)
        cfg = build_cfg(func)
        states = analyze(cfg, analysis)
        seen: Set[Tuple[str, int]] = set()
        for node in cfg.nodes.values():
            if node.kind not in ("stmt", "branch") or node.stmt is None:
                continue
            in_s = states.get(node.nid)
            if in_s is None:
                continue
            reads, writes = _accesses(node.stmt, globals_)
            stmt_awaits = any(contains_await(p)
                              for p in _own_parts(node.stmt))
            for key in sorted(writes):
                if self._sanctioned(key, owners):
                    continue
                line = node.stmt.lineno
                hit = in_s.get(key)
                span = None
                if hit is not None and hit[0] == STALE:
                    span = (hit[1], hit[2])
                elif stmt_awaits and key in reads:
                    # read and write of the same key inside ONE
                    # statement that suspends mid-flight (self.x +=
                    # await f()) — torn without any yield node between
                    span = (line, line)
                if span is None or (key, line) in seen:
                    continue
                seen.add((key, line))
                f = sf.finding(
                    self.name, SimpleNamespace(lineno=line),
                    f"write of {key} uses state read at line {span[0]} "
                    f"across an await at line {span[1]} — another task "
                    f"can interleave there and the update is torn; "
                    f"hold an asyncio.Lock across the span, re-read "
                    f"after the await, or declare single-writer "
                    f"ownership with '# reprolint: owner=<task>' on "
                    f"the field's initialiser")
                if f is not None:
                    yield f
        return


# ---------------------------------------------------------------------------
# checker 11 — blocking-in-async (project-wide)
# ---------------------------------------------------------------------------

#: exact dotted blocking primitives
BLOCKING_DOTTED = frozenset({"time.sleep", "asyncio.run"})
#: dotted-prefix blocking primitives (the whole subprocess surface)
BLOCKING_PREFIXES = ("subprocess.",)
#: leaf names that block regardless of receiver
BLOCKING_LEAVES = frozenset({"run_until_complete"})
#: leaf names that block when called ON a serving session (the
#: session-clock executors: they run scheduler work synchronously)
SESSION_BLOCKING_LEAVES = frozenset({"run_until", "step", "drain"})

_Key = Tuple[str, str]                   # (rel path, qualname)


def _blocking_primitive(call: dict) -> Optional[str]:
    """Human label when ``call`` is a sync blocking primitive (an
    awaited call is a coroutine by construction, not a primitive)."""
    if call.get("awaited"):
        return None
    dn = call["dotted"]
    if dn in BLOCKING_DOTTED or dn.startswith(BLOCKING_PREFIXES):
        return dn
    name = call["name"]
    if name in BLOCKING_LEAVES:
        return dn
    if name in SESSION_BLOCKING_LEAVES:
        recv = dn[:-(len(name) + 1)] if "." in dn else ""
        if "session" in recv:
            return dn
    return None


def _call_suppressed(call: dict) -> bool:
    return bool(call.get("suppressed_blocking"))


class BlockingInAsyncChecker(ProjectChecker):
    name = "blocking-in-async"
    description = ("sync blocking calls (session.run_until/step/drain, "
                   "time.sleep, subprocess, nested event loops) "
                   "reachable from an async def — the event loop stalls "
                   "for their full duration")

    def check_project(self, facts: Dict[str, FileFacts],
                      graph: CallGraph) -> Iterable[Finding]:
        blocked = self._propagate(facts, graph)
        findings: List[Finding] = []
        for rel, ff in sorted(facts.items()):
            if not _in_scope(rel):
                continue
            for fn in ff.functions.values():
                if not fn.is_async:
                    continue
                findings.extend(
                    self._frontier_calls(rel, fn, facts, graph, blocked))
        return findings

    # ------------------------------------------------------------------
    @staticmethod
    def _edge_carries(call: dict, callee_fn) -> bool:
        """Whether loop-blocking taint flows through this call edge: a
        sync callee runs inline; an async callee only runs if awaited
        (an un-awaited coroutine call is a task-leak, not a stall)."""
        return (not callee_fn.is_async) or bool(call.get("awaited"))

    def _propagate(self, facts: Dict[str, FileFacts],
                   graph: CallGraph) -> Dict[_Key, str]:
        """Fixpoint: (rel, qualname) -> witness chain text."""
        blocked: Dict[_Key, str] = {}
        for rel, ff in facts.items():
            for q, fn in ff.functions.items():
                for call in fn.calls:
                    if _call_suppressed(call):
                        continue
                    prim = _blocking_primitive(call)
                    if prim is not None:
                        blocked[(rel, q)] = (
                            f"{q} calls blocking {prim}() at "
                            f"{rel}:{call['line']}")
                        break
        changed = True
        while changed:
            changed = False
            for rel, ff in facts.items():
                for q, fn in ff.functions.items():
                    if (rel, q) in blocked:
                        continue
                    for call in fn.calls:
                        if _call_suppressed(call) \
                                or call["name"] in _BARRIERS:
                            continue
                        hit = None
                        for t in graph.resolve(rel, call):
                            if t in blocked and self._edge_carries(
                                    call, facts[t[0]].functions[t[1]]):
                                hit = t
                                break
                        if hit is not None:
                            blocked[(rel, q)] = (f"{q} calls "
                                                 f"{call['name']}() -> "
                                                 + blocked[hit])
                            changed = True
                            break
        return blocked

    def _frontier_calls(self, rel: str, fn, facts, graph: CallGraph,
                        blocked: Dict[_Key, str]):
        for call in fn.calls:
            if _call_suppressed(call) or call["name"] in _BARRIERS:
                continue
            prim = _blocking_primitive(call)
            if prim is not None:
                yield Finding(
                    checker=self.name, path=rel, line=call["line"],
                    message=(f"blocking call {prim}() on the event loop "
                             f"inside async def {fn.name} — every task "
                             f"stalls for its full duration; await an "
                             f"async equivalent, move it off-loop, or "
                             f"audit the site with a blocking-in-async "
                             f"suppression (the SessionDriver bridge is "
                             f"the one sanctioned place)"),
                    snippet=call["snippet"])
                continue
            hit = None
            for t in graph.resolve(rel, call):
                if t in blocked and self._edge_carries(
                        call, facts[t[0]].functions[t[1]]):
                    hit = t
                    break
            if hit is not None:
                yield Finding(
                    checker=self.name, path=rel, line=call["line"],
                    message=(f"call to {call['name']}() inside async "
                             f"def {fn.name} reaches a blocking "
                             f"primitive ({blocked[hit]}) — the event "
                             f"loop stalls for its full duration; make "
                             f"the chain async or audit the seed with "
                             f"a blocking-in-async suppression"),
                    snippet=call["snippet"])


# ---------------------------------------------------------------------------
# checker 12 — task-leak
# ---------------------------------------------------------------------------

SPAWN_LEAVES = frozenset({"create_task", "ensure_future"})


def _leaf(call: ast.Call) -> str:
    dn = dotted_name(call.func)
    return dn.rsplit(".", 1)[-1] if dn else ""


def _mentions_cancelled(type_expr: Optional[ast.AST]) -> bool:
    if type_expr is None:
        return False
    names = type_expr.elts if isinstance(type_expr, ast.Tuple) \
        else [type_expr]
    for n in names:
        leaf = n.attr if isinstance(n, ast.Attribute) else \
            (n.id if isinstance(n, ast.Name) else "")
        if leaf == "CancelledError":
            return True
    return False


class TaskLeakChecker(Checker):
    name = "task-leak"
    description = ("create_task/ensure_future results dropped or never "
                   "used, coroutines called but never awaited, and "
                   "except CancelledError handlers that swallow without "
                   "re-raising")

    def applies_to(self, sf: SourceFile) -> bool:
        return _in_scope(sf.rel)

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        # a bare call `foo()` can only drop a coroutine when foo is a
        # free async def; `self.foo()` only when foo is an async method
        # of the ENCLOSING class with no same-named sync sibling —
        # `self.driver.start()` (another object's sync start) is not
        # this class's `async def start`
        free_coros = {n.name for n in ast.walk(sf.tree)
                      if isinstance(n, ast.AsyncFunctionDef)
                      and not self._is_method(sf, n)}
        for cls in ast.walk(sf.tree):
            if isinstance(cls, ast.ClassDef):
                amethods = {n.name for n in cls.body
                            if isinstance(n, ast.AsyncFunctionDef)}
                smethods = {n.name for n in cls.body
                            if isinstance(n, ast.FunctionDef)}
                for func in cls.body:
                    if isinstance(func, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        findings.extend(self._check_func(
                            sf, func, free_coros,
                            self_coros=amethods - smethods))
        in_class = {id(f) for cls in ast.walk(sf.tree)
                    if isinstance(cls, ast.ClassDef)
                    for f in cls.body}
        for func in ast.walk(sf.tree):
            if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(func) not in in_class:
                findings.extend(self._check_func(sf, func, free_coros,
                                                 self_coros=set()))
        findings.extend(self._check_cancelled(sf))
        return findings

    @staticmethod
    def _is_method(sf: SourceFile, func: ast.AST) -> bool:
        return any(isinstance(cls, ast.ClassDef) and func in cls.body
                   for cls in ast.walk(sf.tree))

    # ------------------------------------------------------------------
    @staticmethod
    def _shallow_walk(func):
        """Walk ``func``'s own statements, not nested defs' (they get
        their own visit — descending would double-report)."""
        stack = list(ast.iter_child_nodes(func))
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                stack.extend(ast.iter_child_nodes(node))

    def _check_func(self, sf: SourceFile, func, free_coros: Set[str],
                    self_coros: Set[str]):
        # loads use the FULL walk: a closure referencing the handle
        # from a nested def is a legitimate use
        loads = [n.id for n in ast.walk(func)
                 if isinstance(n, ast.Name)
                 and isinstance(n.ctx, ast.Load)]
        for stmt in self._shallow_walk(func):
            if isinstance(stmt, ast.Expr) \
                    and isinstance(stmt.value, ast.Call):
                call = stmt.value
                leaf = _leaf(call)
                if leaf in SPAWN_LEAVES:
                    f = sf.finding(
                        self.name, call,
                        f"{leaf}() result dropped — nothing awaits, "
                        f"cancels or reaps the task, so drain cannot "
                        f"find it and its exceptions vanish; keep the "
                        f"handle (a tracking set, an attribute, a "
                        f"done-callback) and reap it on shutdown")
                    if f is not None:
                        yield f
                else:
                    dn = dotted_name(call.func)
                    dropped = (dn == leaf and leaf in free_coros) or \
                        (dn == f"self.{leaf}" and leaf in self_coros)
                    if dropped:
                        f = sf.finding(
                            self.name, call,
                            f"coroutine {leaf}() is called but never "
                            f"awaited — the call builds a coroutine "
                            f"object and discards it; the body never "
                            f"runs (await it, or hand it to "
                            f"create_task and keep the handle)")
                        if f is not None:
                            yield f
            elif isinstance(stmt, ast.Assign) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and isinstance(stmt.value, ast.Call) \
                    and _leaf(stmt.value) in SPAWN_LEAVES:
                var = stmt.targets[0].id
                if var not in loads:
                    f = sf.finding(
                        self.name, stmt.value,
                        f"task handle {var!r} from "
                        f"{_leaf(stmt.value)}() is never used — the "
                        f"task is spawned fire-and-forget; await it, "
                        f"cancel it, or add it to a tracking set that "
                        f"drain reaps")
                    if f is not None:
                        yield f

    # ------------------------------------------------------------------
    def _check_cancelled(self, sf: SourceFile):
        # the reap idiom is sanctioned per enclosing function: a
        # function that itself .cancel()s a task may swallow the
        # CancelledError it awaits out of it
        cancellers: Set[int] = set()
        for func in ast.walk(sf.tree):
            if not isinstance(func, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if any(isinstance(c, ast.Call)
                   and isinstance(c.func, ast.Attribute)
                   and c.func.attr == "cancel"
                   for c in ast.walk(func)):
                for node in ast.walk(func):
                    if isinstance(node, ast.ExceptHandler):
                        cancellers.add(id(node))
        for handler in ast.walk(sf.tree):
            if not isinstance(handler, ast.ExceptHandler):
                continue
            if not _mentions_cancelled(handler.type):
                continue
            if id(handler) in cancellers:
                continue
            if any(isinstance(n, ast.Raise)
                   for n in ast.walk(handler)):
                continue
            f = sf.finding(
                self.name, handler,
                "except CancelledError swallows the cancellation — "
                "drain's sweep strands here waiting on a task that "
                "ate its own cancel; re-raise after cleanup (only the "
                "code that called .cancel() may absorb it while "
                "reaping)")
            if f is not None:
                yield f
