"""reprolint driver: run every checker over a source tree.

Usage (CI runs exactly this)::

    PYTHONPATH=src python -m repro.analysis.lint src \
        --baseline reprolint.baseline.json

Exit status 0 when every finding is covered by the committed baseline,
1 when any NEW finding exists (print it, fix it, or — exceptionally —
suppress it in-line with a reviewed ``# reprolint: disable=<checker>``
comment). Baseline entries nothing matches anymore are reported as
*stale*: the debt was paid, remove the entry (``--write-baseline``
regenerates the file from the current findings).

The programmatic entry is :func:`run_lint`, used by the checker test
suite to lint fixture snippets and to assert the repo-wide run matches
the committed baseline exactly.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from .asserts import BareAssertChecker
from .base import (Checker, Finding, LintResult, SourceFile,
                   assign_occurrences, load_baseline,
                   split_against_baseline, write_baseline)
from .contracts import BackendContractChecker
from .determinism import DeterminismChecker
from .exceptions import SwallowedExceptionChecker
from .retrace import RetraceHazardChecker
from .sync_points import SyncPointChecker

ALL_CHECKERS: List[Checker] = [
    SyncPointChecker(),
    RetraceHazardChecker(),
    BareAssertChecker(),
    DeterminismChecker(),
    BackendContractChecker(),
    SwallowedExceptionChecker(),
]


def collect_files(paths: Iterable) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def run_lint(paths: Sequence, *, checkers: Optional[Sequence[Checker]] = None,
             baseline: Optional[List[dict]] = None) -> LintResult:
    """Lint ``paths`` (files or directories) and split the findings
    against ``baseline`` (a list of baseline entries; None = empty, so
    every finding is new)."""
    checkers = list(checkers) if checkers is not None else ALL_CHECKERS
    findings: List[Finding] = []
    for path in collect_files(paths):
        try:
            sf = SourceFile(path)
        except SyntaxError as e:
            findings.append(Finding(
                checker="parse-error", path=str(path),
                line=e.lineno or 1,
                message=f"file does not parse: {e.msg}"))
            continue
        for checker in checkers:
            if checker.applies_to(sf):
                findings.extend(checker.check(sf))
    findings = assign_occurrences(findings)
    return split_against_baseline(findings, baseline or [])


def _report(res: LintResult, out=sys.stdout) -> None:
    w = out.write
    for f in res.new:
        w(f"NEW      {f}\n")
    for f in res.baselined:
        w(f"baseline {f.path}:{f.line}: [{f.checker}] (known debt)\n")
    for e in res.stale:
        w(f"STALE    baseline entry {e['fingerprint']} "
          f"({e['checker']} @ {e['path']}) matches nothing — debt paid, "
          f"remove it from the baseline\n")
    w(f"reprolint: {len(res.new)} new, {len(res.baselined)} baselined, "
      f"{len(res.stale)} stale baseline entr"
      f"{'y' if len(res.stale) == 1 else 'ies'}\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="invariant-enforcing static analysis for the serving "
                    "hot path (see repro.analysis for the checker list)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON (default: "
                         "reprolint.baseline.json beside the paths if it "
                         "exists); findings it pins never fail the run")
    ap.add_argument("--write-baseline", metavar="PATH", default=None,
                    help="write ALL current findings to PATH as the new "
                         "baseline and exit 0 (burn-down bookkeeping — "
                         "review the diff!)")
    ap.add_argument("--list-checkers", action="store_true")
    args = ap.parse_args(argv)

    if args.list_checkers:
        for c in ALL_CHECKERS:
            print(f"{c.name:18s} {c.description}")
        return 0

    baseline: List[dict] = []
    if args.baseline:
        baseline = load_baseline(args.baseline)
    else:
        default = Path("reprolint.baseline.json")
        if default.exists():
            baseline = load_baseline(default)

    res = run_lint(args.paths, baseline=baseline)
    if args.write_baseline:
        write_baseline(args.write_baseline, res.findings)
        print(f"wrote {len(res.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0
    _report(res)
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
