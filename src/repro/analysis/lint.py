"""reprolint driver: run every checker over a source tree.

Usage (CI runs exactly this)::

    PYTHONPATH=src python -m repro.analysis.lint src tests benchmarks \
        --baseline reprolint.baseline.json \
        --cache .reprolint-cache.json --format github

Exit status 0 when every finding is covered by the committed baseline,
1 when any NEW finding exists (print it, fix it, or — exceptionally —
suppress it in-line with a reviewed ``# reprolint: disable=<checker>``
comment). Baseline entries nothing matches anymore are reported as
*stale*: the debt was paid, remove the entry (``--write-baseline``
regenerates the file from the current findings).

Two checker tiers run per invocation:

  * per-file checkers (:data:`ALL_CHECKERS`) see one parsed
    :class:`SourceFile` at a time; their findings — and the
    interprocedural *facts* extracted alongside (:mod:`callgraph`) —
    are cached per content hash when ``--cache`` is given, so unchanged
    files are never re-parsed,
  * project checkers (:data:`PROJECT_CHECKERS`) run once over the facts
    of EVERY linted file (cached or fresh), which is how
    ``wallclock-taint`` sees cross-file call chains at warm-cache cost.

``--format github`` additionally emits GitHub Actions
``::error file=...,line=...`` workflow commands for new findings so CI
annotates the offending lines in the diff view.

The programmatic entry is :func:`run_lint`, used by the checker test
suite to lint fixture snippets and to assert the repo-wide run matches
the committed baseline exactly.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

from .asserts import BareAssertChecker
from .asyncrace import (AwaitAtomicityChecker, BlockingInAsyncChecker,
                        TaskLeakChecker)
from .base import (Checker, Finding, LintResult, ProjectChecker, SourceFile,
                   assign_occurrences, load_baseline, rel_path,
                   split_against_baseline, write_baseline)
from .callgraph import CallGraph, FileFacts, extract_facts
from .contracts import BackendContractChecker
from .determinism import DeterminismChecker
from .exceptions import SwallowedExceptionChecker
from .handles import HandleLatticeChecker
from .retrace import RetraceHazardChecker
from .slotleak import SlotLeakChecker
from .sync_points import SyncPointChecker
from .wallclock import WallclockTaintChecker

ALL_CHECKERS: List[Checker] = [
    SyncPointChecker(),
    RetraceHazardChecker(),
    BareAssertChecker(),
    DeterminismChecker(),
    BackendContractChecker(),
    SwallowedExceptionChecker(),
    SlotLeakChecker(),
    HandleLatticeChecker(),
    AwaitAtomicityChecker(),
    TaskLeakChecker(),
]

PROJECT_CHECKERS: List[ProjectChecker] = [
    WallclockTaintChecker(),
    BlockingInAsyncChecker(),
]

#: bump to invalidate every --cache entry (checker semantics changed)
#: v2: async-aware facts — FuncFacts effect summaries (is_async /
#: suspends / self_reads / self_writes) and per-call awaited +
#: blocking-suppression flags; v1 entries must be recomputed, not
#: reused (their facts lack the fields the async checkers read).
CACHE_VERSION = 2

_FINDING_FIELDS = ("checker", "path", "line", "message", "snippet", "file")


def collect_files(paths: Iterable) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def _content_hash(text: str) -> str:
    return hashlib.sha1(
        f"v{CACHE_VERSION}\n{text}".encode()).hexdigest()


def _load_cache(path) -> dict:
    try:
        doc = json.loads(Path(path).read_text())
        if doc.get("version") == CACHE_VERSION:
            return doc.get("files", {})
    except (OSError, ValueError):
        pass
    return {}


def _save_cache(path, files: dict) -> None:
    Path(path).write_text(json.dumps(
        {"version": CACHE_VERSION, "files": files}) + "\n")


def run_lint(paths: Sequence, *, checkers: Optional[Sequence[Checker]] = None,
             project_checkers: Optional[Sequence[ProjectChecker]] = None,
             baseline: Optional[List[dict]] = None,
             cache_path=None) -> LintResult:
    """Lint ``paths`` (files or directories) and split the findings
    against ``baseline`` (a list of baseline entries; None = empty, so
    every finding is new). ``checkers=None`` runs all per-file checkers
    AND all project checkers; an explicit list runs exactly those
    per-file checkers and no project pass (fixture-test mode) unless
    ``project_checkers`` is also given."""
    default_everything = checkers is None and project_checkers is None
    checkers = list(checkers) if checkers is not None else ALL_CHECKERS
    project = (list(project_checkers) if project_checkers is not None
               else (PROJECT_CHECKERS if default_everything else []))

    cache = _load_cache(cache_path) if cache_path else {}
    cache_out: dict = {}
    findings: List[Finding] = []
    all_facts: Dict[str, FileFacts] = {}
    for path in collect_files(paths):
        try:
            text = path.read_text()
        except OSError as e:
            findings.append(Finding(
                checker="parse-error", path=str(path), line=1,
                message=f"file is unreadable: {e}", file=str(path)))
            continue
        key = str(path)
        h = _content_hash(text)
        entry = cache.get(key)
        if entry is not None and entry["hash"] == h \
                and entry["checkers"] == sorted(c.name for c in checkers):
            findings.extend(Finding(**dict(zip(_FINDING_FIELDS, row)))
                            for row in entry["findings"])
            all_facts[entry["rel"]] = FileFacts.from_dict(entry["facts"])
            cache_out[key] = entry
            continue
        try:
            sf = SourceFile(path, text)
        except SyntaxError as e:
            findings.append(Finding(
                checker="parse-error", path=str(path),
                line=e.lineno or 1,
                message=f"file does not parse: {e.msg}", file=str(path)))
            continue
        fresh: List[Finding] = []
        for checker in checkers:
            if checker.applies_to(sf):
                fresh.extend(checker.check(sf))
        facts = extract_facts(sf)
        all_facts[sf.rel] = facts
        findings.extend(fresh)
        cache_out[key] = {
            "hash": h, "rel": sf.rel,
            "checkers": sorted(c.name for c in checkers),
            "findings": [[getattr(f, k) for k in _FINDING_FIELDS]
                         for f in fresh],
            "facts": facts.to_dict(),
        }

    if project:
        graph = CallGraph(all_facts)
        real_of = {rel_path(k): k for k in cache_out}
        for pc in project:
            for f in pc.check_project(all_facts, graph):
                if not f.file:
                    f.file = real_of.get(f.path, f.path)
                findings.append(f)

    if cache_path:
        _save_cache(cache_path, cache_out)
    findings = assign_occurrences(findings)
    return split_against_baseline(findings, baseline or [])


def _report(res: LintResult, out=None) -> None:
    # resolve sys.stdout at call time, not import time — callers (and
    # pytest's capsys) may have swapped the stream since
    w = (out or sys.stdout).write
    for f in res.new:
        w(f"NEW      {f}\n")
    for f in res.baselined:
        w(f"baseline {f.path}:{f.line}: [{f.checker}] (known debt)\n")
    for e in res.stale:
        w(f"STALE    baseline entry {e['fingerprint']} "
          f"({e['checker']} @ {e['path']}) matches nothing — debt paid, "
          f"remove it from the baseline\n")
    w(f"reprolint: {len(res.new)} new, {len(res.baselined)} baselined, "
      f"{len(res.stale)} stale baseline entr"
      f"{'y' if len(res.stale) == 1 else 'ies'}\n")


def _escape_gha(text: str) -> str:
    """GitHub workflow-command data escaping (the documented set)."""
    return (text.replace("%", "%25").replace("\r", "%0D")
            .replace("\n", "%0A"))


def _report_github(res: LintResult, out=None) -> None:
    """GitHub Actions annotations for new findings (plus the human
    summary on top — annotations only render in the web UI)."""
    out = out or sys.stdout
    for f in res.new:
        where = f.file or f.path
        out.write(f"::error file={_escape_gha(where)},line={f.line},"
                  f"title=reprolint {f.checker}::"
                  f"{_escape_gha(f.message)}\n")
    for e in res.stale:
        out.write(f"::error title=reprolint stale baseline::"
                  f"{_escape_gha(str(e.get('fingerprint')))} "
                  f"({e.get('checker')} @ {e.get('path')}) matches "
                  f"nothing — remove the entry\n")
    _report(res, out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="invariant-enforcing static analysis for the serving "
                    "hot path (see repro.analysis for the checker list)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON (default: "
                         "reprolint.baseline.json beside the paths if it "
                         "exists); findings it pins never fail the run")
    ap.add_argument("--write-baseline", metavar="PATH", default=None,
                    help="write ALL current findings to PATH as the new "
                         "baseline and exit 0 (burn-down bookkeeping — "
                         "review the diff!)")
    ap.add_argument("--cache", metavar="PATH", default=None,
                    help="content-hash result cache: unchanged files are "
                         "not re-parsed (interprocedural facts are "
                         "cached alongside, so project checkers still "
                         "see the whole tree)")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="'github' adds ::error workflow-command "
                         "annotations for new findings")
    ap.add_argument("--list-checkers", action="store_true")
    args = ap.parse_args(argv)

    if args.list_checkers:
        for c in ALL_CHECKERS + PROJECT_CHECKERS:
            print(f"{c.name:20s} {c.description}")
        return 0

    baseline: List[dict] = []
    if args.baseline:
        baseline = load_baseline(args.baseline)
    else:
        default = Path("reprolint.baseline.json")
        if default.exists():
            baseline = load_baseline(default)

    res = run_lint(args.paths, baseline=baseline, cache_path=args.cache)
    if args.write_baseline:
        write_baseline(args.write_baseline, res.findings)
        print(f"wrote {len(res.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0
    if args.format == "github":
        _report_github(res)
    else:
        _report(res)
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
