"""Per-function control-flow graphs with explicit exception edges.

The syntactic checkers look at statements one at a time; the PR 7 bug
class (a KV slot stranded when an exception skips the release epilogue)
is a property of *paths*, so the path-sensitive checkers
(:mod:`slotleak`, :mod:`handles`) run over a real CFG instead.

Graph shape
-----------
One :class:`CFG` per ``def``. Nodes are single AST statements plus five
synthetic kinds:

  * ``entry``  — function entry,
  * ``exit``   — normal return / fall-off-the-end,
  * ``raise``  — the exceptional exit (an exception escaping the
    function),
  * ``branch`` — the test of an ``if``/``while`` (or the iteration step
    of a ``for``), with ``true``/``false`` out-edges carrying the test
    expression so analyses can refine state per branch (``is None`` /
    ``is not None`` narrowing),
  * ``yield``  — an explicit YIELD POINT: the event loop may run other
    tasks here. Emitted after any statement containing an ``await``
    expression, at every ``async for`` iteration step (``__anext__`` is
    awaited per item), and at ``async with`` enter/exit (``__aenter__``
    / ``__aexit__`` are awaited). ``stmt`` is the originating statement
    (line reporting); the dataflow engine routes these nodes through
    ``Analysis.suspend`` instead of ``transfer`` so lattices can
    invalidate or check state across the suspension. Yield nodes keep
    live exception edges — an ``await`` is exactly where
    ``CancelledError`` is delivered.

Every statement or branch node gets an ``exc`` out-edge to its current
exception targets: the enclosing ``try``'s handler entries, the
enclosing ``finally`` entry, or the function's ``raise`` exit. Whether
that edge is *live* is the analysis's call (``Analysis.may_raise`` in
:mod:`dataflow`) — the graph over-approximates, the lattice decides.
On an ``exc`` edge the dataflow engine propagates the statement's PRE
state (the statement may not have completed), which is the conservative
direction for may-leak analyses.

Lowering decisions (all over-approximations, safe for may-analyses):

  * ``finally`` bodies are lowered ONCE with multiple continuations:
    normal completions and exceptional escapes both flow into the one
    finally block, and its exit flows to both the after-try point and
    the outer exception targets. States merge at the finally entry —
    coarser than path duplication, but a ``finally`` that releases a
    resource makes every continuation safe, which is the property the
    checkers need.
  * An ``except:``/``except (Base)Exception`` handler is treated as
    catch-all: try-body exceptions then cannot escape past it. Typed
    handlers may not match, so the body also keeps an edge to the outer
    targets.
  * ``with`` is an enter statement plus its body; ``__exit__``
    suppression of exceptions is not modeled (body exceptions flow to
    the enclosing targets — for resource analyses the context manager's
    cleanup must be visible as explicit calls anyway).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

#: edge kinds
NORMAL, EXC, TRUE, FALSE = "normal", "exc", "true", "false"

_CATCH_ALL = ("Exception", "BaseException")


class Node:
    """One CFG node: a statement, a branch test, or a synthetic
    entry/exit/raise node."""

    __slots__ = ("nid", "kind", "stmt", "test")

    def __init__(self, nid: int, kind: str, stmt: Optional[ast.AST] = None,
                 test: Optional[ast.AST] = None):
        self.nid = nid
        self.kind = kind    # entry | exit | raise | stmt | branch | yield
        self.stmt = stmt            # the AST statement (None on synthetic)
        self.test = test            # branch nodes: the test expression

    def __repr__(self):
        what = type(self.stmt).__name__ if self.stmt is not None else ""
        return f"<Node {self.nid} {self.kind} {what}>"


class Edge:
    __slots__ = ("src", "dst", "kind")

    def __init__(self, src: int, dst: int, kind: str):
        self.src = src
        self.dst = dst
        self.kind = kind

    def __repr__(self):
        return f"<Edge {self.src} -{self.kind}-> {self.dst}>"


class CFG:
    """Control-flow graph of one function."""

    def __init__(self, func: ast.AST):
        self.func = func
        self.nodes: Dict[int, Node] = {}
        self.succs: Dict[int, List[Edge]] = {}
        self.preds: Dict[int, List[Edge]] = {}
        self.entry = self._new("entry")
        self.exit = self._new("exit")
        self.raise_exit = self._new("raise")

    # ------------------------------------------------------------------
    def _new(self, kind: str, stmt=None, test=None) -> Node:
        nid = len(self.nodes)
        node = Node(nid, kind, stmt, test)
        self.nodes[nid] = node
        self.succs[nid] = []
        self.preds[nid] = []
        return node

    def _edge(self, src: int, dst: int, kind: str):
        for e in self.succs[src]:
            if e.dst == dst and e.kind == kind:
                return
        e = Edge(src, dst, kind)
        self.succs[src].append(e)
        self.preds[dst].append(e)

    # ------------------------------------------------------------------
    def stmt_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.stmt is not None]


#: a frontier is a list of (node-id, edge-kind) dangling edges awaiting
#: their destination
Frontier = List[Tuple[int, str]]


def contains_await(node: ast.AST) -> bool:
    """Whether ``node`` holds an ``await`` expression OUTSIDE any nested
    function (a nested ``async def``'s awaits suspend the nested
    coroutine, not this one; ``await`` cannot appear in a lambda)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        if isinstance(child, ast.Await) or contains_await(child):
            return True
    return False


def _is_catch_all(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:
        return True
    t = handler.type
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    for n in names:
        base = n.attr if isinstance(n, ast.Attribute) else \
            (n.id if isinstance(n, ast.Name) else "")
        if base in _CATCH_ALL:
            return True
    return False


class _Builder:
    def __init__(self, func: ast.AST):
        self.cfg = CFG(func)
        # stack of (continue-target nid, break-frontier accumulator)
        self.loops: List[Tuple[int, Frontier]] = []

    # ------------------------------------------------------------------
    def build(self) -> CFG:
        exc = [self.cfg.raise_exit.nid]
        frontier = self.stmts(self.cfg.func.body,
                              [(self.cfg.entry.nid, NORMAL)], exc)
        self.connect(frontier, self.cfg.exit.nid)
        return self.cfg

    def connect(self, frontier: Frontier, dst: int):
        for nid, kind in frontier:
            self.cfg._edge(nid, dst, kind)

    def exc_edges(self, nid: int, exc: List[int]):
        for target in exc:
            self.cfg._edge(nid, target, EXC)

    def yield_point(self, stmt: ast.AST, frontier: Frontier,
                    exc: List[int]) -> Frontier:
        """Insert an explicit suspension node: the event loop may run
        other tasks between the in-edges and the out-edge."""
        node = self.cfg._new("yield", stmt)
        self.connect(frontier, node.nid)
        self.exc_edges(node.nid, exc)        # CancelledError delivery
        return [(node.nid, NORMAL)]

    # ------------------------------------------------------------------
    def stmts(self, body: List[ast.stmt], frontier: Frontier,
              exc: List[int]) -> Frontier:
        for stmt in body:
            frontier = self.stmt(stmt, frontier, exc)
        return frontier

    def stmt(self, stmt: ast.stmt, frontier: Frontier,
             exc: List[int]) -> Frontier:
        c = self.cfg
        if isinstance(stmt, ast.If):
            if contains_await(stmt.test):            # `if await x():`
                frontier = self.yield_point(stmt, frontier, exc)
            branch = c._new("branch", stmt, stmt.test)
            self.connect(frontier, branch.nid)
            self.exc_edges(branch.nid, exc)
            t = self.stmts(stmt.body, [(branch.nid, TRUE)], exc)
            f = self.stmts(stmt.orelse, [(branch.nid, FALSE)], exc) \
                if stmt.orelse else [(branch.nid, FALSE)]
            return t + f

        if isinstance(stmt, ast.While):
            # an awaiting test suspends at EVERY evaluation, so the
            # yield node is the loop re-entry point (back edges too)
            loop_entry: Optional[int] = None
            if contains_await(stmt.test):
                frontier = self.yield_point(stmt, frontier, exc)
                loop_entry = frontier[0][0]
            header = c._new("branch", stmt, stmt.test)
            self.connect(frontier, header.nid)
            self.exc_edges(header.nid, exc)
            back = header.nid if loop_entry is None else loop_entry
            breaks: Frontier = []
            self.loops.append((back, breaks))
            body = self.stmts(stmt.body, [(header.nid, TRUE)], exc)
            self.loops.pop()
            self.connect(body, back)                 # loop back edge
            after: Frontier = [(header.nid, FALSE)]
            if stmt.orelse:                          # runs on normal exit
                after = self.stmts(stmt.orelse, after, exc)
            return after + breaks

        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            # the header models the iteration step: TRUE = next item
            # bound, FALSE = iterator exhausted; no test expression.
            # ``async for`` awaits __anext__ per item, so a yield node
            # precedes the header and takes the back edges: every
            # iteration (and the exhaustion probe) passes through it.
            loop_entry = None
            if isinstance(stmt, ast.AsyncFor):
                frontier = self.yield_point(stmt, frontier, exc)
                loop_entry = frontier[0][0]
            header = c._new("branch", stmt, None)
            self.connect(frontier, header.nid)
            self.exc_edges(header.nid, exc)
            back = header.nid if loop_entry is None else loop_entry
            breaks = []
            self.loops.append((back, breaks))
            body = self.stmts(stmt.body, [(header.nid, TRUE)], exc)
            self.loops.pop()
            self.connect(body, back)
            after = [(header.nid, FALSE)]
            if stmt.orelse:
                after = self.stmts(stmt.orelse, after, exc)
            return after + breaks

        if isinstance(stmt, ast.Try):
            return self.try_stmt(stmt, frontier, exc)

        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            enter = c._new("stmt", stmt)
            self.connect(frontier, enter.nid)
            self.exc_edges(enter.nid, exc)           # item exprs can raise
            inner: Frontier = [(enter.nid, NORMAL)]
            if isinstance(stmt, ast.AsyncWith):      # __aenter__ awaited
                inner = self.yield_point(stmt, inner, exc)
            out = self.stmts(stmt.body, inner, exc)
            if isinstance(stmt, ast.AsyncWith):      # __aexit__ awaited
                out = self.yield_point(stmt, out, exc)
            return out

        if isinstance(stmt, ast.Return):
            if contains_await(stmt):                 # value expr awaits
                frontier = self.yield_point(stmt, frontier, exc)
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            self.exc_edges(node.nid, exc)            # value expr can raise
            c._edge(node.nid, c.exit.nid, NORMAL)
            return []

        if isinstance(stmt, ast.Raise):
            if contains_await(stmt):                 # `raise await f()`
                frontier = self.yield_point(stmt, frontier, exc)
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            self.exc_edges(node.nid, exc)            # the ONLY out-edges
            return []

        if isinstance(stmt, ast.Break):
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            if self.loops:
                self.loops[-1][1].append((node.nid, NORMAL))
            return []

        if isinstance(stmt, ast.Continue):
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            if self.loops:
                c._edge(node.nid, self.loops[-1][0], NORMAL)
            return []

        # plain statement (incl. nested def/class, treated opaquely)
        node = c._new("stmt", stmt)
        self.connect(frontier, node.nid)
        self.exc_edges(node.nid, exc)
        out: Frontier = [(node.nid, NORMAL)]
        if contains_await(stmt) and not isinstance(
                stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                       ast.ClassDef)):
            # the statement suspends mid-flight; successors observe the
            # post-suspension world (other tasks ran in between)
            out = self.yield_point(stmt, out, exc)
        return out

    # ------------------------------------------------------------------
    def try_stmt(self, stmt: ast.Try, frontier: Frontier,
                 exc: List[int]) -> Frontier:
        c = self.cfg
        fin_entry: Optional[Node] = None
        fin_frontier: Frontier = []
        # targets an exception escaping THIS try flows to
        escape = exc
        if stmt.finalbody:
            fin_entry = c._new("stmt", stmt)         # anchor for the block
            escape = [fin_entry.nid]

        handler_entries = [c._new("stmt", h) for h in stmt.handlers]
        catch_all = any(_is_catch_all(h) for h in stmt.handlers)
        body_exc = [n.nid for n in handler_entries] \
            + ([] if (catch_all and stmt.handlers) else escape)

        body_frontier = self.stmts(stmt.body, frontier, body_exc)
        # orelse runs only after the body completed without exception
        normal = self.stmts(stmt.orelse, body_frontier, escape) \
            if stmt.orelse else body_frontier
        for h, entry in zip(stmt.handlers, handler_entries):
            normal = normal + self.stmts(h.body, [(entry.nid, NORMAL)],
                                         escape)

        if fin_entry is None:
            return normal
        # finally lowered once: every continuation (normal + escape)
        # funnels through it, and its exit feeds both the after point
        # (the returned frontier) and the outer exception targets
        self.connect(normal, fin_entry.nid)
        fin_frontier = self.stmts(stmt.finalbody,
                                  [(fin_entry.nid, NORMAL)], exc)
        for nid, kind in fin_frontier:
            for target in exc:
                c._edge(nid, target, kind)
        return fin_frontier


def build_cfg(func: ast.AST) -> CFG:
    """Build the CFG of one ``FunctionDef``/``AsyncFunctionDef``."""
    if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
        raise TypeError(f"build_cfg wants a function def, got "
                        f"{type(func).__name__}")
    return _Builder(func).build()


def functions(tree: ast.AST):
    """Yield every (possibly nested) function def in ``tree``."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
