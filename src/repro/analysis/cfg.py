"""Per-function control-flow graphs with explicit exception edges.

The syntactic checkers look at statements one at a time; the PR 7 bug
class (a KV slot stranded when an exception skips the release epilogue)
is a property of *paths*, so the path-sensitive checkers
(:mod:`slotleak`, :mod:`handles`) run over a real CFG instead.

Graph shape
-----------
One :class:`CFG` per ``def``. Nodes are single AST statements plus four
synthetic kinds:

  * ``entry``  — function entry,
  * ``exit``   — normal return / fall-off-the-end,
  * ``raise``  — the exceptional exit (an exception escaping the
    function),
  * ``branch`` — the test of an ``if``/``while`` (or the iteration step
    of a ``for``), with ``true``/``false`` out-edges carrying the test
    expression so analyses can refine state per branch (``is None`` /
    ``is not None`` narrowing).

Every statement or branch node gets an ``exc`` out-edge to its current
exception targets: the enclosing ``try``'s handler entries, the
enclosing ``finally`` entry, or the function's ``raise`` exit. Whether
that edge is *live* is the analysis's call (``Analysis.may_raise`` in
:mod:`dataflow`) — the graph over-approximates, the lattice decides.
On an ``exc`` edge the dataflow engine propagates the statement's PRE
state (the statement may not have completed), which is the conservative
direction for may-leak analyses.

Lowering decisions (all over-approximations, safe for may-analyses):

  * ``finally`` bodies are lowered ONCE with multiple continuations:
    normal completions and exceptional escapes both flow into the one
    finally block, and its exit flows to both the after-try point and
    the outer exception targets. States merge at the finally entry —
    coarser than path duplication, but a ``finally`` that releases a
    resource makes every continuation safe, which is the property the
    checkers need.
  * An ``except:``/``except (Base)Exception`` handler is treated as
    catch-all: try-body exceptions then cannot escape past it. Typed
    handlers may not match, so the body also keeps an edge to the outer
    targets.
  * ``with`` is an enter statement plus its body; ``__exit__``
    suppression of exceptions is not modeled (body exceptions flow to
    the enclosing targets — for resource analyses the context manager's
    cleanup must be visible as explicit calls anyway).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

#: edge kinds
NORMAL, EXC, TRUE, FALSE = "normal", "exc", "true", "false"

_CATCH_ALL = ("Exception", "BaseException")


class Node:
    """One CFG node: a statement, a branch test, or a synthetic
    entry/exit/raise node."""

    __slots__ = ("nid", "kind", "stmt", "test")

    def __init__(self, nid: int, kind: str, stmt: Optional[ast.AST] = None,
                 test: Optional[ast.AST] = None):
        self.nid = nid
        self.kind = kind            # entry | exit | raise | stmt | branch
        self.stmt = stmt            # the AST statement (None on synthetic)
        self.test = test            # branch nodes: the test expression

    def __repr__(self):
        what = type(self.stmt).__name__ if self.stmt is not None else ""
        return f"<Node {self.nid} {self.kind} {what}>"


class Edge:
    __slots__ = ("src", "dst", "kind")

    def __init__(self, src: int, dst: int, kind: str):
        self.src = src
        self.dst = dst
        self.kind = kind

    def __repr__(self):
        return f"<Edge {self.src} -{self.kind}-> {self.dst}>"


class CFG:
    """Control-flow graph of one function."""

    def __init__(self, func: ast.AST):
        self.func = func
        self.nodes: Dict[int, Node] = {}
        self.succs: Dict[int, List[Edge]] = {}
        self.preds: Dict[int, List[Edge]] = {}
        self.entry = self._new("entry")
        self.exit = self._new("exit")
        self.raise_exit = self._new("raise")

    # ------------------------------------------------------------------
    def _new(self, kind: str, stmt=None, test=None) -> Node:
        nid = len(self.nodes)
        node = Node(nid, kind, stmt, test)
        self.nodes[nid] = node
        self.succs[nid] = []
        self.preds[nid] = []
        return node

    def _edge(self, src: int, dst: int, kind: str):
        for e in self.succs[src]:
            if e.dst == dst and e.kind == kind:
                return
        e = Edge(src, dst, kind)
        self.succs[src].append(e)
        self.preds[dst].append(e)

    # ------------------------------------------------------------------
    def stmt_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.stmt is not None]


#: a frontier is a list of (node-id, edge-kind) dangling edges awaiting
#: their destination
Frontier = List[Tuple[int, str]]


def _is_catch_all(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:
        return True
    t = handler.type
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    for n in names:
        base = n.attr if isinstance(n, ast.Attribute) else \
            (n.id if isinstance(n, ast.Name) else "")
        if base in _CATCH_ALL:
            return True
    return False


class _Builder:
    def __init__(self, func: ast.AST):
        self.cfg = CFG(func)
        # stack of (continue-target nid, break-frontier accumulator)
        self.loops: List[Tuple[int, Frontier]] = []

    # ------------------------------------------------------------------
    def build(self) -> CFG:
        exc = [self.cfg.raise_exit.nid]
        frontier = self.stmts(self.cfg.func.body,
                              [(self.cfg.entry.nid, NORMAL)], exc)
        self.connect(frontier, self.cfg.exit.nid)
        return self.cfg

    def connect(self, frontier: Frontier, dst: int):
        for nid, kind in frontier:
            self.cfg._edge(nid, dst, kind)

    def exc_edges(self, nid: int, exc: List[int]):
        for target in exc:
            self.cfg._edge(nid, target, EXC)

    # ------------------------------------------------------------------
    def stmts(self, body: List[ast.stmt], frontier: Frontier,
              exc: List[int]) -> Frontier:
        for stmt in body:
            frontier = self.stmt(stmt, frontier, exc)
        return frontier

    def stmt(self, stmt: ast.stmt, frontier: Frontier,
             exc: List[int]) -> Frontier:
        c = self.cfg
        if isinstance(stmt, ast.If):
            branch = c._new("branch", stmt, stmt.test)
            self.connect(frontier, branch.nid)
            self.exc_edges(branch.nid, exc)
            t = self.stmts(stmt.body, [(branch.nid, TRUE)], exc)
            f = self.stmts(stmt.orelse, [(branch.nid, FALSE)], exc) \
                if stmt.orelse else [(branch.nid, FALSE)]
            return t + f

        if isinstance(stmt, ast.While):
            header = c._new("branch", stmt, stmt.test)
            self.connect(frontier, header.nid)
            self.exc_edges(header.nid, exc)
            breaks: Frontier = []
            self.loops.append((header.nid, breaks))
            body = self.stmts(stmt.body, [(header.nid, TRUE)], exc)
            self.loops.pop()
            self.connect(body, header.nid)           # loop back edge
            after: Frontier = [(header.nid, FALSE)]
            if stmt.orelse:                          # runs on normal exit
                after = self.stmts(stmt.orelse, after, exc)
            return after + breaks

        if isinstance(stmt, ast.For):
            # the header models the iteration step: TRUE = next item
            # bound, FALSE = iterator exhausted; no test expression
            header = c._new("branch", stmt, None)
            self.connect(frontier, header.nid)
            self.exc_edges(header.nid, exc)
            breaks = []
            self.loops.append((header.nid, breaks))
            body = self.stmts(stmt.body, [(header.nid, TRUE)], exc)
            self.loops.pop()
            self.connect(body, header.nid)
            after = [(header.nid, FALSE)]
            if stmt.orelse:
                after = self.stmts(stmt.orelse, after, exc)
            return after + breaks

        if isinstance(stmt, ast.Try):
            return self.try_stmt(stmt, frontier, exc)

        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            enter = c._new("stmt", stmt)
            self.connect(frontier, enter.nid)
            self.exc_edges(enter.nid, exc)           # item exprs can raise
            return self.stmts(stmt.body, [(enter.nid, NORMAL)], exc)

        if isinstance(stmt, ast.Return):
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            self.exc_edges(node.nid, exc)            # value expr can raise
            c._edge(node.nid, c.exit.nid, NORMAL)
            return []

        if isinstance(stmt, ast.Raise):
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            self.exc_edges(node.nid, exc)            # the ONLY out-edges
            return []

        if isinstance(stmt, ast.Break):
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            if self.loops:
                self.loops[-1][1].append((node.nid, NORMAL))
            return []

        if isinstance(stmt, ast.Continue):
            node = c._new("stmt", stmt)
            self.connect(frontier, node.nid)
            if self.loops:
                c._edge(node.nid, self.loops[-1][0], NORMAL)
            return []

        # plain statement (incl. nested def/class, treated opaquely)
        node = c._new("stmt", stmt)
        self.connect(frontier, node.nid)
        self.exc_edges(node.nid, exc)
        return [(node.nid, NORMAL)]

    # ------------------------------------------------------------------
    def try_stmt(self, stmt: ast.Try, frontier: Frontier,
                 exc: List[int]) -> Frontier:
        c = self.cfg
        fin_entry: Optional[Node] = None
        fin_frontier: Frontier = []
        # targets an exception escaping THIS try flows to
        escape = exc
        if stmt.finalbody:
            fin_entry = c._new("stmt", stmt)         # anchor for the block
            escape = [fin_entry.nid]

        handler_entries = [c._new("stmt", h) for h in stmt.handlers]
        catch_all = any(_is_catch_all(h) for h in stmt.handlers)
        body_exc = [n.nid for n in handler_entries] \
            + ([] if (catch_all and stmt.handlers) else escape)

        body_frontier = self.stmts(stmt.body, frontier, body_exc)
        # orelse runs only after the body completed without exception
        normal = self.stmts(stmt.orelse, body_frontier, escape) \
            if stmt.orelse else body_frontier
        for h, entry in zip(stmt.handlers, handler_entries):
            normal = normal + self.stmts(h.body, [(entry.nid, NORMAL)],
                                         escape)

        if fin_entry is None:
            return normal
        # finally lowered once: every continuation (normal + escape)
        # funnels through it, and its exit feeds both the after point
        # (the returned frontier) and the outer exception targets
        self.connect(normal, fin_entry.nid)
        fin_frontier = self.stmts(stmt.finalbody,
                                  [(fin_entry.nid, NORMAL)], exc)
        for nid, kind in fin_frontier:
            for target in exc:
                c._edge(nid, target, kind)
        return fin_frontier


def build_cfg(func: ast.AST) -> CFG:
    """Build the CFG of one ``FunctionDef``/``AsyncFunctionDef``."""
    if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
        raise TypeError(f"build_cfg wants a function def, got "
                        f"{type(func).__name__}")
    return _Builder(func).build()


def functions(tree: ast.AST):
    """Yield every (possibly nested) function def in ``tree``."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
