"""Checker 9 — ``wallclock-taint``: interprocedural wall-time taint.

The determinism checker used to match wall-clock reads per line, per
module — which a one-line helper defeats: put ``time.perf_counter()`` in
``launch/`` and call it from ``core/`` and no rule fires, yet the sim's
virtual clock is now polluted and no replay is bit-identical. This
checker closes the laundering hole with call-graph taint propagation
(the intraprocedural wall-clock rule is retired from ``determinism``).

  * **sources** — ``time.time/perf_counter/monotonic/...``,
    ``datetime.now/utcnow/today`` reads anywhere in the scanned tree.
    A read carrying a ``# reprolint: disable=wallclock-taint``
    suppression is an *audited boundary*: it neither reports nor taints
    its function (this is how ``launch/roofline.py``'s probe timings
    stay legal). Whole modules whose job is wall time — the serving
    gateway — are declared in
    :data:`~repro.analysis.base.WALLCLOCK_AUDITED_PREFIXES` and audited
    as a unit, with the same no-report/no-taint semantics.
  * **propagation** — a function is tainted if it reads a source or
    calls a tainted function (resolved over the import neighborhood;
    see :mod:`callgraph`). Backend-contract method names are
    polymorphic **barriers**: ``backend.execute_run(...)`` is the
    sanctioned wall-time boundary (the session clock advances by the
    returned latency — virtual under the simulator, measured under the
    JAX engine), so taint never crosses them. Suppressed call sites
    don't propagate either.
  * **sinks** — inside virtual-time modules (``core/``, the sim-path
    serving modules, ``benchmarks/fig*``): any direct source read, and
    any call that reaches a tainted function. Reported at the read /
    call site with the witness chain down to the clock read.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

from .base import (Finding, ProjectChecker, is_virtual_time_file,
                   is_wallclock_audited)
from .callgraph import BARRIER_METHODS as _BARRIERS
from .callgraph import CallGraph, FileFacts

_Key = Tuple[str, str]                   # (rel path, qualname)


class WallclockTaintChecker(ProjectChecker):
    name = "wallclock-taint"
    description = ("wall-clock reads reaching virtual-time modules, "
                   "directly or laundered through the call graph")

    def check_project(self, facts: Dict[str, FileFacts],
                      graph: CallGraph) -> Iterable[Finding]:
        tainted = self._propagate(facts, graph)
        findings: List[Finding] = []
        for rel, ff in sorted(facts.items()):
            if not is_virtual_time_file(rel):
                continue
            for fn in ff.functions.values():
                for read in fn.clock_reads:
                    if read["suppressed"]:
                        continue
                    findings.append(Finding(
                        checker=self.name, path=rel, line=read["line"],
                        message=(f"wall-clock read {read['dotted']}() in "
                                 f"a virtual-time module — sim time must "
                                 f"come from the event clock"),
                        snippet=read["snippet"]))
                findings.extend(
                    self._tainted_calls(rel, fn, graph, tainted))
        return findings

    # ------------------------------------------------------------------
    @staticmethod
    def _propagate(facts: Dict[str, FileFacts],
                   graph: CallGraph) -> Dict[_Key, str]:
        """Fixpoint: (rel, qualname) -> witness chain text."""
        tainted: Dict[_Key, str] = {}
        for rel, ff in facts.items():
            if is_wallclock_audited(rel):
                # a declared wall-clock boundary (the serving gateway):
                # its reads are audited as a unit, so they neither
                # report nor seed taint — exactly like a per-line
                # suppression, minus the line noise
                continue
            for q, fn in ff.functions.items():
                for read in fn.clock_reads:
                    if not read["suppressed"]:
                        tainted[(rel, q)] = (f"{q} reads "
                                             f"{read['dotted']}() at "
                                             f"{rel}:{read['line']}")
                        break
        changed = True
        while changed:
            changed = False
            for rel, ff in facts.items():
                for q, fn in ff.functions.items():
                    if (rel, q) in tainted:
                        continue
                    for call in fn.calls:
                        if call["suppressed"] or call["name"] in _BARRIERS:
                            continue
                        hit = next(
                            (t for t in graph.resolve(rel, call)
                             if t in tainted), None)
                        if hit is not None:
                            tainted[(rel, q)] = (f"{q} calls "
                                                 f"{call['name']}() -> "
                                                 + tainted[hit])
                            changed = True
                            break
        return tainted

    @staticmethod
    def _tainted_calls(rel: str, fn, graph: CallGraph,
                       tainted: Dict[_Key, str]):
        for call in fn.calls:
            if call["suppressed"] or call["name"] in _BARRIERS:
                continue
            hit = next((t for t in graph.resolve(rel, call)
                        if t in tainted), None)
            if hit is None:
                continue
            yield Finding(
                checker="wallclock-taint", path=rel, line=call["line"],
                message=(f"call to {call['name']}() launders wall time "
                         f"into a virtual-time module "
                         f"({tainted[hit]}) — route the value through "
                         f"the event clock or audit the read with a "
                         f"wallclock-taint suppression at the source"),
                snippet=call["snippet"])
