"""Checker 8 — ``handle-lattice``: fate writes must be legal lifecycle
edges.

PR 7 pinned the handle lifecycle to a monotone-except-retry state
machine; :mod:`repro.core.lifecycle` is now its single declarative
table, imported by the runtime (``serving.session`` derives its enum
and disposition buckets from it, ``core.request`` validates fate writes
at runtime) AND by this checker — so the code that moves handles and
the analysis that polices the moves cannot disagree.

Rules (scope: ``serving/session.py`` + ``core/request.py``, the only
modules that write lifecycle state):

  * a literal fate write ``obj.fate = "x"`` must name a declared fate
    (``lifecycle.FATES``); ``obj.fate = None`` is a terminal→live
    backward edge (terminals are absorbing) and is illegal outside
    ``__init__``,
  * a **non-literal** fate write is only legal inside a declared fate
    funnel (``lifecycle.FATE_SETTER_FUNCTIONS`` — the one place that
    validates dynamically),
  * the rollback writes encoding the one backward edge
    (``lifecycle.ROLLBACK_WRITES``: ``t_first_issue = None``,
    ``idx = 0``, ``_running = False``) are only legal inside the
    declared retry functions (``lifecycle.RETRY_FUNCTIONS``) or an
    ``__init__``,
  * path-sensitively (CFG + fixpoint): two *different* literal fates
    reaching the same object on one path is a terminal→terminal edge —
    the absorbing property violated even though each write looks fine
    in isolation.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

from ..core import lifecycle
from .base import Checker, Finding, SourceFile
from .cfg import build_cfg, functions
from .dataflow import Analysis, analyze

_INIT_FUNCTIONS = frozenset({"__init__"})


def _own_stmts(func) -> Iterable[ast.AST]:
    """Walk ``func``'s own body, NOT descending into nested defs (those
    are visited as functions in their own right)."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(n))


def _fate_write(stmt: ast.AST) -> Optional[Tuple[str, ast.AST]]:
    """(base-expr-text, value) when ``stmt`` is ``<base>.fate = value``."""
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
        t = stmt.targets[0]
        if isinstance(t, ast.Attribute) and t.attr == "fate":
            try:
                return ast.unparse(t.value), stmt.value
            except Exception:
                return "?", stmt.value
    return None


def _rollback_write(stmt: ast.AST) -> Optional[str]:
    """The attribute name when ``stmt`` is one of the declared rollback
    writes (attribute assignment of the exact rewind literal)."""
    if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1):
        return None
    t = stmt.targets[0]
    if not (isinstance(t, ast.Attribute)
            and t.attr in lifecycle.ROLLBACK_WRITES):
        return None
    expected = lifecycle.ROLLBACK_WRITES[t.attr]
    v = stmt.value
    # repr-compare: False == 0 in Python, but False is not a rewind of idx
    if isinstance(v, ast.Constant) and repr(v.value) == repr(expected):
        return t.attr
    return None


class _FateAnalysis(Analysis):
    """base-expr -> frozenset of literal fates already written on some
    path; used to detect terminal→terminal edges."""

    def join_values(self, a: FrozenSet[str], b: FrozenSet[str]):
        return a | b

    def transfer(self, state, stmt):
        fw = _fate_write(stmt)
        if fw is None:
            return state
        base, value = fw
        if isinstance(value, ast.Constant) and isinstance(value.value, str):
            out = dict(state)
            out[base] = state.get(base, frozenset()) | {value.value}
            return out
        return state


class HandleLatticeChecker(Checker):
    name = "handle-lattice"
    description = ("fate/rollback writes that are not legal edges of "
                   "the declarative lifecycle table (core.lifecycle)")

    def applies_to(self, sf: SourceFile) -> bool:
        return sf.rel.endswith("repro/serving/session.py") \
            or sf.rel.endswith("repro/core/request.py")

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for func in functions(sf.tree):
            findings.extend(self._check_writes(sf, func))
            findings.extend(self._check_absorbing(sf, func))
        return [f for f in findings if f is not None]

    # -- syntactic, table-driven ---------------------------------------
    def _check_writes(self, sf: SourceFile, func):
        for stmt in _own_stmts(func):
            if not isinstance(stmt, ast.Assign):
                continue
            fw = _fate_write(stmt)
            if fw is not None:
                base, value = fw
                if isinstance(value, ast.Constant):
                    if value.value is None:
                        if func.name not in _INIT_FUNCTIONS:
                            yield sf.finding(
                                self.name, stmt,
                                f"{base}.fate = None clears a terminal "
                                f"disposition — terminals are absorbing "
                                f"(no edge back to a live state)")
                    elif value.value not in lifecycle.FATES:
                        yield sf.finding(
                            self.name, stmt,
                            f"{base}.fate = {value.value!r} is not a "
                            f"declared terminal disposition "
                            f"(lifecycle.FATES = "
                            f"{', '.join(lifecycle.FATES)})")
                elif func.name not in lifecycle.FATE_SETTER_FUNCTIONS:
                    yield sf.finding(
                        self.name, stmt,
                        f"non-literal fate write in {func.name}() — "
                        f"dynamic fates must route through the declared "
                        f"funnel(s) "
                        f"{sorted(lifecycle.FATE_SETTER_FUNCTIONS)} "
                        f"where the lifecycle table validates them")
                continue
            attr = _rollback_write(stmt)
            if attr is not None \
                    and func.name not in lifecycle.RETRY_FUNCTIONS \
                    and func.name not in _INIT_FUNCTIONS:
                rewind = lifecycle.ROLLBACK_WRITES[attr]
                yield sf.finding(
                    self.name, stmt,
                    f"{attr} = {rewind!r} rewinds the handle lattice "
                    f"(the RUNNING -> QUEUED retry edge) outside the "
                    f"declared retry function(s) "
                    f"{sorted(lifecycle.RETRY_FUNCTIONS)} — an illegal "
                    f"backward edge")

    # -- path-sensitive absorbing rule ---------------------------------
    def _check_absorbing(self, sf: SourceFile, func):
        writes = [n for n in _own_stmts(func)
                  if _fate_write(n) is not None
                  and isinstance(_fate_write(n)[1], ast.Constant)
                  and isinstance(_fate_write(n)[1].value, str)]
        if len(writes) < 2:
            return                       # absorbing needs two writes
        cfg = build_cfg(func)
        states = analyze(cfg, _FateAnalysis())
        for node in cfg.stmt_nodes():
            fw = _fate_write(node.stmt)
            if fw is None:
                continue
            base, value = fw
            if not (isinstance(value, ast.Constant)
                    and isinstance(value.value, str)):
                continue
            prior = states.get(node.nid, {}).get(base, frozenset())
            others = prior - {value.value}
            if others:
                yield sf.finding(
                    self.name, node.stmt,
                    f"on some path {base}.fate was already "
                    f"{'/'.join(sorted(others))!r} before this write of "
                    f"{value.value!r} — fates are absorbing, a second "
                    f"different fate is a terminal -> terminal edge")
