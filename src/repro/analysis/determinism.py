"""Checker 4 — ``determinism``: unseeded RNG and set-order ties.

The simulator's clock is VIRTUAL: every latency, deadline, and slack
value derives from the discrete-event ``session.now``, which is what
makes traces replayable bit-identically and sim/JAX parity testable. An
unseeded RNG draw, or a scheduling tiebreak that iterates a ``set`` in
hash order inside those modules injects nondeterminism no equivalence
grid can catch — the run still "passes", just differently every time.

Scope: the virtual-time modules (``core/``, sim-path serving modules,
``benchmarks/fig*``) plus the audited launch tools (``roofline.py`` /
``dryrun.py``).

Rules:

  * unseeded / global-state RNG: ``np.random.default_rng()`` with no
    seed, module-level ``np.random.<draw>()`` (global RNG), stdlib
    ``random.<draw>()``, ``np.random.seed`` (global-state mutation),
  * iteration-order-dependent tiebreaks: ``min``/``max``/``sorted`` with
    a ``key=`` over a ``set`` literal/comprehension/call — elements the
    key maps equal resolve by set iteration order, which varies across
    processes (PYTHONHASHSEED) for str elements. (Key-less min/max/
    sorted over comparable elements is a total order and stays clean.)

Wall-clock reads used to be a third rule family here; they are now the
``wallclock-taint`` project checker (:mod:`wallclock`), which also
catches the interprocedural laundering this per-line rule never could —
a helper in ``launch/`` reading ``perf_counter()`` for a caller in
``core/``.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import (Checker, Finding, SourceFile, dotted_name,
                   is_virtual_time_file)

_GLOBAL_RNG_DRAWS = {
    "random", "rand", "randn", "randint", "integers", "choice", "shuffle",
    "permutation", "normal", "uniform", "poisson", "exponential", "seed",
}
_STDLIB_RANDOM = {
    "random.random", "random.randint", "random.choice", "random.shuffle",
    "random.uniform", "random.sample", "random.gauss", "random.seed",
}
_ORDER_SENSITIVE = {"min", "max", "sorted"}


class DeterminismChecker(Checker):
    name = "determinism"
    description = ("unseeded RNG / set-iteration tiebreaks in "
                   "virtual-time modules (wall clock: wallclock-taint)")

    def applies_to(self, sf: SourceFile) -> bool:
        return is_virtual_time_file(sf.rel)

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            msg = self._classify(call)
            if msg is None:
                continue
            f = sf.finding(self.name, call, msg)
            if f is not None:
                findings.append(f)
        return findings

    # ------------------------------------------------------------------
    def _classify(self, call: ast.Call):
        dn = dotted_name(call.func)
        if dn in _STDLIB_RANDOM:
            return (f"{dn}() draws from the global stdlib RNG — use a "
                    f"seeded np.random.default_rng(seed) stream")
        if dn == "np.random.default_rng" or dn == "numpy.random.default_rng":
            if not call.args and not call.keywords:
                return ("np.random.default_rng() without a seed — replay "
                        "determinism requires an explicit seed")
            return None
        if dn.startswith(("np.random.", "numpy.random.")):
            leaf = dn.rsplit(".", 1)[1]
            if leaf in _GLOBAL_RNG_DRAWS:
                return (f"{dn}() uses numpy's GLOBAL RNG state — use a "
                        f"seeded np.random.default_rng(seed) stream")
        if isinstance(call.func, ast.Name) \
                and call.func.id in _ORDER_SENSITIVE and call.args:
            has_key = any(kw.arg == "key" for kw in call.keywords)
            if has_key and self._is_set_expr(call.args[0]):
                return (f"{call.func.id}(..., key=...) over a set — "
                        f"key-equal elements resolve by set iteration "
                        f"order, which is process-dependent for str "
                        f"elements; make the key a total order or sort "
                        f"a sequence instead")
        return None

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("set", "frozenset"))
