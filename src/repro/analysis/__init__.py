"""reprolint: repo-specific static analysis enforcing serving invariants.

Every perf win in this repo rests on invariants that used to exist only
as convention — one host sync per committed run, no wall-clock or
unseeded RNG in virtual-time paths, bounded retraces via pow2 bucketing,
no ``assert``-guarded runtime invariants (they vanish under ``python
-O``), and the model-keyed Backend contract. This package makes them
*enforced*: a lint pass (``python -m repro.analysis.lint src tests
benchmarks``) with twelve repo-specific checkers — six line-level AST
matchers plus six that run real dataflow (per-function CFGs with
exception edges and explicit ``await`` yield-point nodes, a
suspension-aware worklist fixpoint engine, and an import-resolved
call graph carrying per-function effect summaries; :mod:`cfg`,
:mod:`dataflow`, :mod:`callgraph`) — reported
against a committed baseline (new findings fail CI; the baseline is
empty and must stay so), plus cheap runtime sanitizer counters in the
JAX engine (``Backend.sanitizer_stats()``) that let a test assert "N
decode cycles => <= 1 sync per run and 0 retraces after warmup".

Checkers (see each module's docstring for the precise rules):

  * ``sync-point``       — host-device sync constructs inside the
    engine's run-execution hot paths (``sync_points``),
  * ``retrace-hazard``   — dynamic shape-derived scalars flowing into
    jit-cache keys outside the pow2 bucketing helpers (``retrace``),
  * ``bare-assert``      — runtime invariants guarded by ``assert`` in
    production code (``asserts``; tests are exempt — pytest asserts
    are the point there),
  * ``determinism``      — unseeded RNG / set-iteration tiebreaks in
    virtual-time modules (``determinism``; wall-clock reads moved to
    ``wallclock-taint``),
  * ``backend-contract`` — Backend subclasses drifting off the
    model-keyed signatures, classes defining only half of the
    ``reset_request``/``release_request`` residency pair, or internal
    use of the retired ``Executor`` alias (``contracts``),
  * ``swallowed-exception`` — bare/trivial handlers that eat backend
    faults (``exceptions``),
  * ``slot-leak``        — path-sensitive CFG analysis: any path
    (including exception edges) on which an acquired KV slot leaves a
    serving function neither released nor owned (``slotleak``),
  * ``handle-lattice``   — fate/rollback writes that are not legal
    edges of the declarative lifecycle table shared with the runtime
    (``handles``, :mod:`repro.core.lifecycle`),
  * ``wallclock-taint``  — interprocedural taint: wall-clock reads
    reaching virtual-time modules through the call graph, however many
    helpers they are laundered through (``wallclock``),
  * ``await-atomicity``  — suspension-aware CFG analysis: shared state
    (``self.*`` / globals) read before and written after an ``await``
    with no ``asyncio.Lock`` held and no single-writer ownership
    annotation — another task can interleave in the window and the
    update is torn (``asyncrace``),
  * ``blocking-in-async`` — interprocedural loop-stall taint: sync
    blocking primitives (``session.run_until``/``step``/``drain``,
    ``time.sleep``, ``subprocess``, nested event loops) reachable from
    an ``async def`` through the call graph; the audited SessionDriver
    bridge seeds carry suppressions, so every transitive caller is
    sanctioned at once (``asyncrace``),
  * ``task-leak``        — dropped ``create_task``/``ensure_future``
    handles, coroutines called but never awaited, and ``except
    CancelledError`` handlers that swallow the cancellation outside
    the cancel-and-reap idiom (``asyncrace``).

Suppress a legitimate finding with a trailing (or preceding-line)
comment: ``# reprolint: disable=<checker>[,<checker>]``. Declare a
shared attribute single-writer (pump-task-only, so ``await-atomicity``
spans on it are sanctioned file-wide) with ``# reprolint:
owner=<task>`` on its initialising assignment.
"""
# NOTE: .lint is deliberately NOT imported here — ``python -m
# repro.analysis.lint`` would otherwise import it twice (runpy warning).
# Import ALL_CHECKERS / run_lint from repro.analysis.lint directly.
from .base import (Finding, LintResult, load_baseline, write_baseline)

__all__ = ["Finding", "LintResult", "load_baseline", "write_baseline"]
