"""reprolint: repo-specific static analysis enforcing serving invariants.

Every perf win in this repo rests on invariants that used to exist only
as convention — one host sync per committed run, no wall-clock or
unseeded RNG in virtual-time paths, bounded retraces via pow2 bucketing,
no ``assert``-guarded runtime invariants (they vanish under ``python
-O``), and the model-keyed Backend contract. This package makes them
*enforced*: an AST lint pass (``python -m repro.analysis.lint src/``)
with six repo-specific checkers, reported against a committed baseline
(new findings fail CI; legacy ones are burned down), plus cheap runtime
sanitizer counters in the JAX engine (``Backend.sanitizer_stats()``)
that let a test assert "N decode cycles => <= 1 sync per run and 0
retraces after warmup".

Checkers (see each module's docstring for the precise rules):

  * ``sync-point``       — host-device sync constructs inside the
    engine's run-execution hot paths (``sync_points``),
  * ``retrace-hazard``   — dynamic shape-derived scalars flowing into
    jit-cache keys outside the pow2 bucketing helpers (``retrace``),
  * ``bare-assert``      — runtime invariants guarded by ``assert`` in
    production code (``asserts``),
  * ``determinism``      — wall-clock / unseeded RNG / set-iteration
    tiebreaks in virtual-time modules (``determinism``),
  * ``backend-contract`` — Backend subclasses drifting off the
    model-keyed signatures, or internal use of the retired ``Executor``
    alias (``contracts``),
  * ``swallowed-exception`` — bare/trivial handlers that eat backend
    faults, and serving ``try`` bodies that can strand an acquired KV
    slot without a finally/handler release (``exceptions``).

Suppress a legitimate finding with a trailing (or preceding-line)
comment: ``# reprolint: disable=<checker>[,<checker>]``.
"""
# NOTE: .lint is deliberately NOT imported here — ``python -m
# repro.analysis.lint`` would otherwise import it twice (runpy warning).
# Import ALL_CHECKERS / run_lint from repro.analysis.lint directly.
from .base import (Finding, LintResult, load_baseline, write_baseline)

__all__ = ["Finding", "LintResult", "load_baseline", "write_baseline"]
