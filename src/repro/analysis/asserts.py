"""Checker 3 — ``bare-assert``: runtime invariants that vanish under -O.

``assert`` compiles to nothing under ``python -O``: an invariant guarded
by one is an invariant that silently stops being checked the moment
someone runs optimized bytecode. PR 5 shipped exactly this bug in
``ServingSession.release()`` — a live-handle release guard that
evaporated under -O and orphaned KV slots. The fix pattern (mirrored by
this checker's message) is a typed exception with a message::

    if not handle.done:
        raise ValueError(f"cannot release live request {rid} ...")

Every ``assert`` statement in production code is flagged; the test
tree (``tests/``) is exempt — ``assert`` is pytest's native idiom
there, rewritten (not stripped) by its assertion machinery. The last
legacy sites — trace-time shape preconditions in Pallas kernel wrappers
and the training smoke gate — were converted to typed exceptions when
the baseline was burned to zero; the baseline stays empty.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile, is_test_file


class BareAssertChecker(Checker):
    name = "bare-assert"
    description = ("assert-guarded runtime invariants in production "
                   "code (removed entirely under python -O)")

    def applies_to(self, sf: SourceFile) -> bool:
        # pytest rewrites (never strips) test asserts: exempt tests/
        return not is_test_file(sf.rel)

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assert):
                continue
            cond = ast.unparse(node.test) if hasattr(ast, "unparse") else ""
            f = sf.finding(
                self.name, node,
                f"bare assert guards a runtime invariant "
                f"({cond[:60]!r}) — raise a typed exception with a "
                f"message instead (vanishes under python -O)")
            if f is not None:
                findings.append(f)
        return findings
