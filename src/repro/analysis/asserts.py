"""Checker 3 — ``bare-assert``: runtime invariants that vanish under -O.

``assert`` compiles to nothing under ``python -O``: an invariant guarded
by one is an invariant that silently stops being checked the moment
someone runs optimized bytecode. PR 5 shipped exactly this bug in
``ServingSession.release()`` — a live-handle release guard that
evaporated under -O and orphaned KV slots. The fix pattern (mirrored by
this checker's message) is a typed exception with a message::

    if not handle.done:
        raise ValueError(f"cannot release live request {rid} ...")

Every ``assert`` statement in production code (``src/``) is flagged;
test files are out of scope by construction (the lint runs on ``src``).
The committed baseline carries the residual legacy sites — trace-time
shape preconditions in Pallas kernel wrappers and the training smoke
gate — as debt, not as precedent.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile


class BareAssertChecker(Checker):
    name = "bare-assert"
    description = ("assert-guarded runtime invariants in production "
                   "code (removed entirely under python -O)")

    def applies_to(self, sf: SourceFile) -> bool:
        # scope = whatever tree the lint was pointed at (src/); test
        # files use assert idiomatically and are not scanned
        return True

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assert):
                continue
            cond = ast.unparse(node.test) if hasattr(ast, "unparse") else ""
            f = sf.finding(
                self.name, node,
                f"bare assert guards a runtime invariant "
                f"({cond[:60]!r}) — raise a typed exception with a "
                f"message instead (vanishes under python -O)")
            if f is not None:
                findings.append(f)
        return findings
