"""Checker 2 — ``retrace-hazard``: dynamic scalars leaking into jit keys.

The engine bounds recompiles by power-of-two bucketing: every Python
scalar that reaches a jit-cache key (the ``_fn_*`` getter arguments —
layer bounds, context buckets, head flags) must be either structurally
static or bucketed through ``_pow2``. A raw shape/length-derived scalar
in a key means one fresh XLA compile per distinct value — the classic
"bench regressed 20% and nobody knows why" failure.

Rules, scoped to ``serving/engine.py``:

  * an argument to a ``self._fn_*(...)`` getter whose expression contains
    ``len(...)``, ``.shape``, or a per-request dynamic attribute
    (``.pos`` / ``.prefill_len`` / ``.decode_len``) is a hazard UNLESS
    the containing expression routes through the ``_pow2`` bucketing
    helper (``_pow2(x)``, ``min(_pow2(x), cap)``, ...),
  * ``jax.jit(...)`` may only be called inside the memoized ``_fn_*``
    getters — a jit created on the run-execution path builds (and traces)
    a fresh callable per invocation.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, SourceFile, dotted_name, is_engine_file

_DYNAMIC_ATTRS = {"pos", "prefill_len", "decode_len"}
_BUCKET_HELPERS = {"_pow2"}


def _contains_bucketing(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fn = sub.func
            name = fn.id if isinstance(fn, ast.Name) else \
                (fn.attr if isinstance(fn, ast.Attribute) else "")
            if name in _BUCKET_HELPERS:
                return True
    return False


def _dynamic_source(node: ast.AST):
    """The first shape/length-derived source inside ``node`` (name of the
    construct), or None."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "len":
            return "len(...)"
        if isinstance(sub, ast.Attribute):
            if sub.attr == "shape":
                return ".shape"
            if sub.attr in _DYNAMIC_ATTRS:
                return f".{sub.attr}"
    return None


class RetraceHazardChecker(Checker):
    name = "retrace-hazard"
    description = ("shape/length-derived Python scalars flowing into "
                   "jit-cache keys outside the pow2 bucketing helpers")

    def applies_to(self, sf: SourceFile) -> bool:
        return is_engine_file(sf.rel)

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        # enclosing-function map for the jax.jit placement rule
        enclosing = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(node):
                    enclosing.setdefault(sub, node.name)
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            fn = call.func
            # rule 1: dynamic scalars in _fn_* getter args
            if isinstance(fn, ast.Attribute) and fn.attr.startswith("_fn_"):
                for arg in list(call.args) + [kw.value for kw in
                                              call.keywords]:
                    src = _dynamic_source(arg)
                    if src is not None and not _contains_bucketing(arg):
                        f = sf.finding(
                            self.name, call,
                            f"argument to jit-key getter '{fn.attr}' "
                            f"derives from {src} without _pow2 bucketing "
                            f"— every distinct value retraces")
                        if f is not None:
                            findings.append(f)
            # rule 2: jax.jit outside the memoized getters
            if dotted_name(fn) == "jax.jit":
                owner = enclosing.get(call, "")
                if not owner.startswith("_fn_"):
                    f = sf.finding(
                        self.name, call,
                        f"jax.jit called in '{owner or '<module>'}' — "
                        f"jits must be built once inside memoized _fn_* "
                        f"getters, or every call re-traces")
                    if f is not None:
                        findings.append(f)
        return findings
