"""NPU latency model + traffic generator tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.serving import (get_workload, poisson_trace, bursty_trace,
                           colocated_trace, NPUPerfModel, PAPER_NPU, TPU_V5E)
from repro.serving.workload import NodeDesc

PERF = NPUPerfModel(PAPER_NPU)


# Table II calibration: single-batch latencies within a 2x band.
@pytest.mark.parametrize("name,target_ms", [
    ("resnet", 1.1), ("gnmt", 7.2), ("transformer", 2.4)])
def test_table2_single_batch_latency(name, target_ms):
    wl = get_workload(name)
    p = wl.prompt_dist.quantile(0.5) if wl.prompt_dist else 0
    d = wl.decode_dist.quantile(0.5) if wl.decode_dist else 0
    ours = PERF.single_input_exec_time(wl, p, d) * 1e3
    assert target_ms / 2 <= ours <= target_ms * 2, (name, ours, target_ms)


@settings(max_examples=30, deadline=None)
@given(flops=st.floats(1e6, 1e12), wb=st.floats(1e3, 1e9),
       b1=st.integers(1, 32), b2=st.integers(1, 32))
def test_batching_amortizes_per_sample_latency(flops, wb, b1, b2):
    """Latency/sample is non-increasing in batch size (Fig. 3 blue curve)."""
    node = NodeDesc("n", flops, wb, act_bytes=1e3)
    if b1 > b2:
        b1, b2 = b2, b1
    l1 = PERF.node_latency(node, [128] * b1) / b1
    l2 = PERF.node_latency(node, [128] * b2) / b2
    assert l2 <= l1 * (1 + 1e-9)


@settings(max_examples=30, deadline=None)
@given(flops=st.floats(1e6, 1e12), wb=st.floats(1e3, 1e9),
       batch=st.integers(1, 64))
def test_latency_monotone_in_batch_and_ctx(flops, wb, batch):
    node = NodeDesc("n", flops, wb, act_bytes=1e3, flops_per_ctx=flops / 100,
                    bytes_per_ctx=16.0)
    l_small = PERF.node_latency(node, [10] * batch)
    l_big = PERF.node_latency(node, [1000] * batch)
    assert l_big >= l_small
    l_more = PERF.node_latency(node, [10] * (batch + 1))
    assert l_more >= l_small


def test_throughput_saturates_with_batch():
    """Fig. 3: effective throughput rises then levels out."""
    wl = get_workload("resnet")
    def thr(n):
        lat = sum(PERF.node_latency(nd, [1] * n)
                  for nd, _ in ((wl.nodes[i], 0) for i in wl.nodes))
        return n / lat
    t1, t16, t64 = thr(1), thr(16), thr(64)
    assert t16 > 1.8 * t1                     # batching helps a lot early
    assert t64 < t16 * 1.5                    # ... then levels out


def test_poisson_trace_statistics():
    wl = get_workload("resnet")
    rate, dur = 500, 4.0
    tr = poisson_trace(wl, rate, dur, seed=3)
    n = len(tr)
    assert abs(n - rate * dur) < 4 * np.sqrt(rate * dur)
    gaps = np.diff([r.arrival for r in tr.requests])
    assert abs(gaps.mean() - 1 / rate) / (1 / rate) < 0.15


def test_bursty_and_colocated_traces():
    wl1, wl2 = get_workload("resnet"), get_workload("transformer")
    tr = bursty_trace(wl1, 50, 500, switch_period=0.5, duration=2.0, seed=0)
    assert len(tr) > 0
    co = colocated_trace([wl1, wl2], [100, 100], duration=1.0, seed=0)
    names = {r.workload.name for r in co.requests}
    assert names == {"resnet", "transformer"}
    arr = [r.arrival for r in co.requests]
    assert arr == sorted(arr)


def test_tpu_profile_is_faster():
    wl = get_workload("resnet")
    t_npu = PERF.single_input_exec_time(wl, 0, 0)
    t_tpu = NPUPerfModel(TPU_V5E).single_input_exec_time(wl, 0, 0)
    assert t_tpu < t_npu
