"""Unit tests for BatchTable stack semantics (paper Fig. 10 walk-through)."""
import pytest

from repro.core.batch_table import BatchTable
from repro.core.request import Request, SubBatch


def mk_req(node_ids, arrival=0.0):
    return Request(workload=None, arrival=arrival,
                   sequence=[(n, 1) for n in node_ids])


def test_fig10_walkthrough():
    """Reproduce the paper's Fig. 10 BatchTable trace (8-node graph A..H)."""
    nodes = list("ABCDEFGH")
    bt = BatchTable(max_batch=64)

    # t=2: Req1 arrives, pushed at node A
    r1 = mk_req(nodes)
    bt.push([r1])
    assert bt.active.node_id == "A"

    # Req1 executes A, B; at end of B, Req2 is pushed (preempting Req1)
    r1.advance(); r1.advance()
    assert r1.next_node_id == "C"
    r2 = mk_req(nodes)
    bt.push([r2])
    assert bt.active.live_requests == [r2]
    assert bt.num_entries == 2

    # Req2 executes A; Req3 arrives and is pushed (t=5)
    r2.advance()
    r3 = mk_req(nodes)
    bt.push([r3])
    assert bt.num_entries == 3

    # Req3 executes A -> now Req2 and Req3 both at node B: merge (t=6)
    r3.advance()
    assert bt.merge_top() == 1
    assert bt.num_entries == 2
    assert sorted(r.rid for r in bt.active.live_requests) == sorted(
        [r2.rid, r3.rid])
    assert bt.active.node_id == "B"

    # merged Req2-3 execute B -> all three at node C: merge again (t=7)
    r2.advance(); r3.advance()
    assert bt.merge_top() == 1
    assert bt.num_entries == 1
    assert bt.active.size == 3
    assert bt.active.node_id == "C"


def test_merge_respects_max_batch():
    bt = BatchTable(max_batch=2)
    r1, r2, r3 = mk_req("AB"), mk_req("AB"), mk_req("AB")
    bt.push([r1, r2])
    bt.push([r3])
    assert bt.merge_top() == 0          # 2 + 1 > max_batch
    assert bt.num_entries == 2


def test_subbatch_invariant_detects_divergence():
    r1, r2 = mk_req("AB"), mk_req("AB")
    sb = SubBatch([r1, r2])
    r1.advance()
    with pytest.raises(RuntimeError, match="different nodes"):
        _ = sb.node_id


def test_finished_members_leave_subbatch():
    r1, r2 = mk_req("A"), mk_req("AB")
    sb = SubBatch([r1, r2])
    done = sb.advance(now=1.0)
    assert done == [r1]
    assert r1.t_finish == 1.0
    assert sb.live_requests == [r2]
    assert sb.node_id == "B"
