"""Property test (hypothesis): the failure model under ANY interleaving.

Random interleavings of {run a step, cancel a live request, inject a
transient fault into the next dispatch} over a real JaxEngine session
must preserve the failure-model invariants:

  * the arena free pool stays an EXACT partition of the slot range after
    every step (no leak, no double-issue) — eviction, retry-release, and
    batch release compose with grow/shrink;
  * handle lifecycle is monotone and terminal: state rank only moves
    backward when a fault retry rewound the request (its ``retries``
    counter grew), and a terminal state is absorbing;
  * survivors — requests that complete despite the chaos — produce
    tokens BIT-EXACT equal to the same seed's fault-free run.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import LazyBatching, SlackPredictor
from repro.serving import (HandleState, NPUPerfModel, RetryPolicy, PAPER_NPU,
                           ServingSession, TransientBackendError)
from repro.serving.engine import JaxEngine
from test_engine_memory import _pool_consistent, _tiny, _workload

_CFG = _tiny()
_WL = _workload(_CFG)
_PERF = NPUPerfModel(PAPER_NPU)

_RANK = {HandleState.QUEUED: 0, HandleState.ADMITTED: 1,
         HandleState.RUNNING: 2}
_TERMINAL = (HandleState.DONE, HandleState.REJECTED, HandleState.CANCELLED,
             HandleState.EXPIRED, HandleState.FAILED, HandleState.SHED)


class _ArmedFaults(JaxEngine):
    """JaxEngine that raises one retryable fault when armed."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.armed = False

    def execute_run(self, model, sb, node_ids):
        if self.armed:
            self.armed = False
            raise TransientBackendError("armed fault", latency=0.0)
        return super().execute_run(model, sb, node_ids)


def _serve(ops):
    engine = _ArmedFaults(_CFG, max_len=32, n_slots=2, max_slots=8,
                          min_slots=2)
    pol = LazyBatching(SlackPredictor.build([_WL], _PERF, 60.0),
                       max_batch=4)
    session = ServingSession(pol, engine, seed=77,
                             retry=RetryPolicy(max_retries=100,
                                               backoff_base=1e-4))
    rng = np.random.default_rng(31)
    handles = [session.submit(_WL.sample_request(rng, 0.0))
               for _ in range(4)]
    last = {h.request.rid: (h.state, h.retries) for h in handles}

    def check():
        _pool_consistent(engine)
        for h in handles:
            prev_state, prev_retries = last[h.request.rid]
            state, retries = h.state, h.retries
            if prev_state in _TERMINAL:
                assert state is prev_state, \
                    f"terminal state changed: {prev_state} -> {state}"
            elif state not in _TERMINAL:
                if _RANK[state] < _RANK[prev_state]:
                    assert retries > prev_retries, \
                        f"{prev_state} -> {state} without a retry"
            last[h.request.rid] = (state, retries)

    for op in ops:
        if op == 1:
            live = [h for h in handles if not h.done]
            if live:
                live[0].cancel()
        elif op == 2:
            engine.armed = True
        if not session.step():
            break
        check()
    engine.armed = False                 # drain fault-free
    while session.step():
        check()
    return engine, handles


@settings(max_examples=6, deadline=None, derandomize=True,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=st.lists(st.integers(0, 2), min_size=1, max_size=12))
def test_failure_model_invariants_under_any_interleaving(ops):
    engine, handles = _serve(ops)
    # everything terminal, nothing resident, pool an exact partition
    assert all(h.done for h in handles)
    assert engine.slots_in_use == 0
    _pool_consistent(engine)
    # survivors bit-exact vs the fault-free run of the same seed
    _, clean = _serve([])
    assert all(h.state is HandleState.DONE for h in clean)
    for h, ref in zip(handles, clean):
        if h.state is HandleState.DONE:
            assert h.tokens == ref.tokens
