"""Multi-tenant serving: ModelRegistry + cross-model SLA arbitration.

Covers the registry redesign's contracts:
  * a single registered model is BIT-identical to the legacy
    single-model ``ServingSession`` across the policy × rate grid,
  * per-model RNG streams: adding/reordering mixture components never
    perturbs another model's sampled arrivals or lengths,
  * two-model overload: the LazyBatching stack (per-model lazyb policies
    + least-slack arbiter) beats per-model GraphBatching round-robin on
    aggregate SLA attainment, and the tight-SLA model's p99 stays far
    below the bulk model's (per-model p99 ordering),
  * MultiBackend routes every model-keyed call to the right backend,
  * round-robin arbitration alternates between backlogged models,
  * per-model stats are NaN-safe for registered-but-idle models,
  * the retired ``Executor`` alias warns and resolves to ``Backend``.
"""
import numpy as np
import pytest

from repro.core import (GraphBatching, LazyBatching, LeastSlackArbiter,
                        Oracle, OracleSlackPredictor, RoundRobinArbiter,
                        Serial, SLAClass, SlackPredictor)
from repro.serving import (Backend, MultiBackend, NPUPerfModel, PAPER_NPU,
                           ServingSession, SimExecutor, get_workload,
                           poisson_mixture, poisson_trace, run_mixture,
                           run_trace)

PERF = NPUPerfModel(PAPER_NPU)

WL = {name: get_workload(name)
      for name in ("transformer", "gnmt", "resnet")}


def make_policy(kind, wl, sla=0.1, max_batch=16):
    if kind == "serial":
        return Serial()
    if kind == "graphb":
        return GraphBatching(window=0.01, max_batch=max_batch)
    if kind == "lazyb":
        return LazyBatching(SlackPredictor.build([wl], PERF, sla),
                            max_batch=max_batch)
    return Oracle(OracleSlackPredictor(sla, PERF), max_batch=max_batch)


# ---------------------------------------------------------------------------
# Registry equivalence: one registered model == the legacy session, exactly
# ---------------------------------------------------------------------------

def _request_key(stats):
    """Exact per-request timing signature (float equality intended)."""
    return sorted((r.rid, r.t_first_issue, r.t_first_token, r.t_finish)
                  for r in stats.finished)


@pytest.mark.parametrize("kind", ["serial", "graphb", "lazyb", "oracle"])
@pytest.mark.parametrize("rate", [150, 700])
def test_registered_single_model_bit_identical(kind, rate):
    wl = WL["transformer"]
    trace = poisson_trace(wl, rate, 0.06, seed=3)

    legacy = run_trace(make_policy(kind, wl), SimExecutor(PERF),
                       trace.fresh())

    session = ServingSession(backend=SimExecutor(PERF))
    session.register("tfm", wl, policy=make_policy(kind, wl))
    t2 = trace.fresh()
    session.duration = t2.duration
    for r in sorted(t2.requests, key=lambda r: r.arrival):
        session.submit(r, model="tfm")
    registered = session.drain()

    assert _request_key(legacy) == _request_key(registered)
    assert legacy.summary(sla=0.1)["p99_ms"] == \
        registered.summary(sla=0.1)["p99_ms"]


def test_legacy_constructor_registers_default_model():
    wl = WL["transformer"]
    session = ServingSession(make_policy("lazyb", wl), SimExecutor(PERF))
    assert session.registry.names() == ["default"]
    rng = np.random.default_rng(0)
    h = session.submit(wl.sample_request(rng, 0.0))
    session.drain()
    # the handle carries the routing key; the request keeps its (absent)
    # tag so per-model stats fall back to the workload name
    assert h.model == "default"
    assert h.request.model is None
    assert h.request.model_name == wl.name


def test_single_model_session_keeps_workload_fallback_in_per_model():
    """A legacy co-located trace (several workloads, ONE policy/session)
    still breaks down per workload in ServeStats.per_model()."""
    from repro.serving import colocated_trace

    wa, wb = WL["transformer"], WL["resnet"]
    trace = colocated_trace([wa, wb], [200, 200], 0.05, seed=0)
    pred = SlackPredictor.build([wa, wb], PERF, 0.1)
    stats = run_trace(LazyBatching(pred, max_batch=16), SimExecutor(PERF),
                      trace.fresh())
    pm = stats.per_model()
    assert {"transformer", "resnet"} <= set(pm)
    assert pm["transformer"]["completed"] > 0
    assert pm["resnet"]["completed"] > 0


# ---------------------------------------------------------------------------
# Per-model RNG streams (determinism regression)
# ---------------------------------------------------------------------------

def _stream_sig(trace, model):
    return [(r.arrival, r.prompt_len, r.decode_len, r.model)
            for r in trace.requests if r.model == model]


def test_mixture_streams_survive_extra_model_and_reordering():
    wa, wb, wc = WL["transformer"], WL["gnmt"], WL["resnet"]
    two = poisson_mixture([("a", wa, 300), ("b", wb, 200)], 0.3, seed=7)
    three = poisson_mixture([("a", wa, 300), ("c", wc, 500), ("b", wb, 200)],
                            0.3, seed=7)
    swapped = poisson_mixture([("b", wb, 200), ("a", wa, 300)], 0.3, seed=7)
    for m in ("a", "b"):
        assert _stream_sig(two, m) == _stream_sig(three, m), \
            f"registering model c perturbed model {m}'s stream"
        assert _stream_sig(two, m) == _stream_sig(swapped, m), \
            f"reordering the mixture perturbed model {m}'s stream"
    # arrival-sorted superposition, tagged throughout
    arr = [r.arrival for r in three.requests]
    assert arr == sorted(arr)
    assert three.models == ("a", "b", "c")
    # different seeds give different streams (the key actually feeds in)
    other = poisson_mixture([("a", wa, 300)], 0.3, seed=8)
    assert _stream_sig(two, "a") != _stream_sig(other, "a")


def test_mixture_fresh_preserves_model_tags():
    mix = poisson_mixture([("a", WL["transformer"], 300),
                           ("b", WL["gnmt"], 200)], 0.1, seed=0)
    clone = mix.fresh()
    assert [r.model for r in clone.requests] == \
        [r.model for r in mix.requests]


# ---------------------------------------------------------------------------
# Two-model overload: SLA-aware arbitration vs round-robin GraphBatching
# ---------------------------------------------------------------------------

GOLD, BULK = SLAClass("gold", 0.04), SLAClass("bulk", 0.4)


def _gold_bulk_mixture(seed=0, duration=0.25):
    """Interactive (gold, 40 ms) transformer co-located with a batchy
    (bulk, 400 ms) GNMT under combined overload — the paper's §VI-C
    co-location shape."""
    mix = poisson_mixture([("tf", WL["transformer"], 600),
                           ("gn", WL["gnmt"], 400)], duration, seed=seed)
    for r in mix.requests:
        r.sla = GOLD if r.model == "tf" else BULK
    return mix


def _serve_gold_bulk(mix, kind, arbiter):
    models = [("tf", WL["transformer"], make_policy(kind, WL["transformer"])),
              ("gn", WL["gnmt"], make_policy(kind, WL["gnmt"]))]
    return run_mixture(models, SimExecutor(PERF), mix.fresh(),
                       arbiter=arbiter)


@pytest.mark.parametrize("seed", [0, 1])
def test_lazyb_arbiter_beats_graphb_round_robin(seed):
    """Acceptance: on a two-model overload mixture the LazyBatching
    cross-model arbiter beats per-model GraphBatching round-robin on
    aggregate SLA attainment (each request judged against its own class
    deadline)."""
    mix = _gold_bulk_mixture(seed=seed)
    lazy = _serve_gold_bulk(mix, "lazyb", LeastSlackArbiter())
    base = _serve_gold_bulk(mix, "graphb", RoundRobinArbiter())
    assert len(lazy.finished) == len(base.finished) == len(mix.requests)
    a_lazy, a_base = lazy.attainment(), base.attainment()
    assert a_lazy > a_base + 0.2, \
        f"lazyb+least-slack {a_lazy:.3f} vs graphb+rr {a_base:.3f}"
    assert a_lazy > 0.9


def test_two_model_overload_per_model_p99_ordering():
    """The tight-SLA model's p99 must sit far below the bulk model's
    under the SLA-aware arbiter, and both classes hold their own SLAs."""
    mix = _gold_bulk_mixture(seed=0)
    stats = _serve_gold_bulk(mix, "lazyb", LeastSlackArbiter())
    pm = stats.per_model()
    assert set(pm) == {"tf", "gn"}
    assert pm["tf"]["completed"] > 0 and pm["gn"]["completed"] > 0
    # per-model p99 ordering: interactive model far below the batch model
    assert pm["tf"]["p99_ms"] < 0.5 * pm["gn"]["p99_ms"], pm
    # both models still attain their own (very different) deadlines
    assert pm["tf"]["sla_attainment"] > 0.9
    assert pm["gn"]["sla_attainment"] > 0.9
    # per-class view agrees (gold == tf, bulk == gn here)
    pc = stats.per_class()
    assert pc["gold"]["p99_ms"] < pc["bulk"]["p99_ms"]
    # summary carries the per-model keys for multi-tenant runs
    s = stats.summary()
    assert "p99_ms[model:tf]" in s and "sla_viol[model:gn]" in s


def test_least_slack_prefers_urgent_model_over_rr_order():
    """Direct arbiter unit check: with two ready candidates the one whose
    request is closest to violation dispatches first regardless of
    registration order; round-robin alternates instead."""
    wl = WL["resnet"]

    class _Entry:
        def __init__(self, name, index):
            self.name, self.index, self.policy = name, index, Serial()

    rng = np.random.default_rng(0)
    urgent = wl.sample_request(rng, 0.0)
    urgent.sla = SLAClass("tight", 0.01)
    relaxed = wl.sample_request(rng, 0.0)
    relaxed.sla = SLAClass("loose", 10.0)
    from repro.core.request import SubBatch
    cand = [(_Entry("a", 0), SubBatch([relaxed]), ("conv1",)),
            (_Entry("b", 1), SubBatch([urgent]), ("conv1",))]
    assert LeastSlackArbiter().pick(cand, now=0.005) == 1
    rr = RoundRobinArbiter()
    assert rr.pick(cand, now=0.0) == 0
    assert rr.pick(cand, now=0.0) == 1          # alternates
    assert rr.pick(cand, now=0.0) == 0


# ---------------------------------------------------------------------------
# MultiBackend routing + model resolution
# ---------------------------------------------------------------------------

class SpyBackend(Backend):
    def __init__(self, latency=1e-3):
        self.latency = latency
        self.calls = []                 # (model, node_id, rids)
        self.prepared = []
        self.finished = []

    def prepare(self, model, req, rng, prompt_tokens=None):
        self.prepared.append((model, req.rid))

    def execute(self, model, sb, node_id):
        self.calls.append((model, node_id,
                           tuple(r.rid for r in sb.live_requests)))
        return self.latency

    def on_finished(self, model, reqs):
        self.finished.extend(r.rid for r in reqs)


def _mixture_session(spy_a, spy_b, arbiter=None):
    wl_a, wl_b = WL["resnet"], WL["transformer"]
    session = ServingSession(
        backend=MultiBackend({"a": spy_a, "b": spy_b}), arbiter=arbiter)
    session.register("a", wl_a, policy=Serial())
    session.register("b", wl_b, policy=Serial())
    return session, wl_a, wl_b


def test_multibackend_routes_per_model():
    spy_a, spy_b = SpyBackend(), SpyBackend()
    session, wl_a, wl_b = _mixture_session(spy_a, spy_b)
    rng = np.random.default_rng(0)
    ra = [wl_a.sample_request(rng, 0.0) for _ in range(2)]
    rb = [wl_b.sample_request(rng, 0.0) for _ in range(2)]
    for r in ra:
        session.submit(r, model="a")
    for r in rb:
        session.submit(r, model="b")
    stats = session.drain()
    assert len(stats.finished) == 4
    # every call reached the right spy, with the right model key
    assert {m for m, _, _ in spy_a.calls} == {"a"}
    assert {m for m, _, _ in spy_b.calls} == {"b"}
    rids_a = {r.rid for r in ra}
    assert {rid for _, _, rids in spy_a.calls for rid in rids} == rids_a
    assert set(spy_a.finished) == rids_a
    assert {m for m, _ in spy_a.prepared} == {"a"}
    # device-time shares: both models on the one session clock
    assert session.log.busy_by_model["a"] > 0
    assert session.log.busy_by_model["b"] > 0
    assert spy_a.calls and spy_b.calls


def test_round_robin_alternates_between_backlogged_models():
    dispatch_order = []

    class OrderSpy(SpyBackend):
        def __init__(self, tag):
            super().__init__()
            self.tag = tag

        def execute_run(self, model, sb, node_ids):
            dispatch_order.append(model)
            return super().execute_run(model, sb, node_ids)

    session, wl_a, wl_b = _mixture_session(OrderSpy("a"), OrderSpy("b"),
                                           arbiter=RoundRobinArbiter())
    rng = np.random.default_rng(1)
    for _ in range(3):
        session.submit(wl_a.sample_request(rng, 0.0), model="a")
        session.submit(wl_b.sample_request(rng, 0.0), model="b")
    session.drain()
    # Serial commits one whole graph per run: with both models backlogged
    # the round-robin arbiter strictly alternates their runs
    assert dispatch_order == ["a", "b"] * 3
    # multi-model sessions prefix per-run log keys with the model name
    assert any(k.startswith("a:") for k in session.log.node_lat)
    assert any(k.startswith("b:") for k in session.log.node_lat)


def test_submit_model_resolution_and_validation():
    wl_a, wl_b = WL["resnet"], WL["transformer"]
    session = ServingSession(backend=SimExecutor(PERF))
    session.register("a", wl_a, policy=Serial())
    session.register("b", wl_b, policy=Serial())
    rng = np.random.default_rng(0)

    with pytest.raises(KeyError, match="not registered"):
        session.submit(wl_a.sample_request(rng, 0.0), model="nope")
    with pytest.raises(ValueError, match="no model tag"):
        session.submit(wl_a.sample_request(rng, 0.0))      # ambiguous
    with pytest.raises(ValueError, match="serves"):
        session.submit(wl_b.sample_request(rng, 0.0), model="a")
    # a tagged request routes itself
    r = wl_b.sample_request(rng, 0.0)
    r.model = "b"
    h = session.submit(r)
    session.drain()
    assert h.done and h.model == "b"


def test_duplicate_model_name_rejected():
    session = ServingSession(backend=SimExecutor(PERF))
    session.register("a", WL["resnet"], policy=Serial())
    with pytest.raises(ValueError, match="already registered"):
        session.register("a", WL["resnet"], policy=Serial())


# ---------------------------------------------------------------------------
# Per-model stats: NaN-safe for idle models
# ---------------------------------------------------------------------------

def test_per_model_stats_nan_safe_for_idle_model():
    wl = WL["transformer"]
    session = ServingSession(backend=SimExecutor(PERF))
    session.register("busy", wl, policy=make_policy("lazyb", wl))
    session.register("idle", WL["resnet"], policy=Serial())
    rng = np.random.default_rng(0)
    for _ in range(3):
        session.submit(wl.sample_request(rng, 0.0), model="busy")
    stats = session.drain()
    pm = stats.per_model(0.1)
    assert set(pm) == {"busy", "idle"}
    assert pm["idle"]["completed"] == 0
    assert np.isnan(pm["idle"]["p99_ms"])
    assert np.isnan(pm["idle"]["sla_attainment"])
    assert pm["busy"]["completed"] == 3
    # registered models recorded on the stats (policy names included)
    assert stats.models == {"busy": "lazyb", "idle": "serial"}


# ---------------------------------------------------------------------------
# Real JAX engines behind a MultiBackend (two models, one device clock)
# ---------------------------------------------------------------------------

def test_jax_two_model_mixture_through_multibackend():
    import dataclasses

    from repro.configs import get_config
    from repro.serving import TPU_V5E
    from repro.serving.engine import JaxEngine
    from repro.serving.workload import LengthDist, from_model_config

    def tiny(arch):
        cfg = get_config(arch).reduced()
        return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                                   num_prefix_embeddings=0)

    dists = dict(prompt_dist=LengthDist((5, 7), (0.5, 0.5)),
                 decode_dist=LengthDist((2, 3), (0.5, 0.5)))
    cfg_a, cfg_b = tiny("llama3.2-1b"), tiny("mamba2-2.7b")
    wl_a = from_model_config(cfg_a, **dists)
    wl_b = from_model_config(cfg_b, **dists)
    engines = {"llama": JaxEngine(cfg_a, max_len=32, n_slots=8),
               "mamba": JaxEngine(cfg_b, max_len=32, n_slots=8)}
    perf = NPUPerfModel(TPU_V5E)

    def pol(wl):
        return LazyBatching(SlackPredictor.build([wl], perf, 60.0),
                            max_batch=4)

    session = ServingSession(backend=MultiBackend(engines),
                             arbiter=LeastSlackArbiter(sla_default=60.0))
    session.register("llama", wl_a, policy=pol(wl_a))
    session.register("mamba", wl_b, policy=pol(wl_b))
    rng = np.random.default_rng(0)
    handles, t = [], 0.0
    for i in range(4):
        t += rng.exponential(0.01)
        wl, name = ((wl_a, "llama") if i % 2 == 0 else (wl_b, "mamba"))
        handles.append(session.submit(wl.sample_request(rng, t), model=name))
    stats = session.drain()
    assert len(stats.finished) == 4
    for h in handles:
        assert h.done and len(h.tokens) == h.request.decode_len
        # streamed tokens match the owning engine's batch results
        eng = engines[h.model]
        assert h.tokens == eng.states[h.request.rid].generated
    pm = stats.per_model()
    assert pm["llama"]["completed"] == 2 and pm["mamba"]["completed"] == 2
    # both engines' wall-clock accumulated on the one session clock
    assert session.log.busy_by_model["llama"] > 0
    assert session.log.busy_by_model["mamba"] > 0
    assert session.now >= sum(session.log.busy_by_model.values()) - 1e-9
    # slots all released on drain, on both engines
    assert all(e.slots_in_use == 0 for e in engines.values())


# ---------------------------------------------------------------------------
# Retired Executor alias
# ---------------------------------------------------------------------------

def test_executor_alias_warns_and_resolves_to_backend():
    import repro.serving as serving
    import repro.serving.server as server
    from repro.serving.backend import Backend as B
    with pytest.warns(DeprecationWarning, match="Executor is deprecated"):
        assert server.Executor is B
    with pytest.warns(DeprecationWarning):
        assert serving.Executor is B
    with pytest.raises(AttributeError):
        server.NoSuchThing
