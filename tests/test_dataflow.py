"""Dataflow framework suite: CFG lowering, the worklist engine, and the
three path-sensitive checkers (slot-leak, handle-lattice,
wallclock-taint) against seeded violations and their clean twins.

Fixture files live under ``tmp_path/repro/...`` (or ``tmp_path/tests``,
``tmp_path/benchmarks``) because checker scoping keys on the
repo-relative suffix after the last path marker — same convention as
``test_reprolint.py``.
"""
import ast
from pathlib import Path

import pytest

from repro.analysis.cfg import (EXC, FALSE, NORMAL, TRUE, build_cfg,
                                functions)
from repro.analysis.dataflow import Analysis, analyze
from repro.analysis.lint import ALL_CHECKERS, PROJECT_CHECKERS, run_lint
from repro.core import lifecycle

REPO = Path(__file__).resolve().parents[1]


def _func(src: str, name: str = None) -> ast.FunctionDef:
    tree = ast.parse(src)
    for f in functions(tree):
        if name is None or f.name == name:
            return f
    raise AssertionError(f"no function {name!r} in fixture")


def _write(tmp_path: Path, rel: str, text: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def _lint(tmp_path, rel, text, checker):
    p = _write(tmp_path, rel, text)
    return run_lint([p], checkers=[c for c in ALL_CHECKERS
                                   if c.name == checker])


def _lint_project(paths):
    """Full project-checker run (wallclock-taint) over ``paths``."""
    return run_lint(paths, checkers=[], project_checkers=PROJECT_CHECKERS)


# ---------------------------------------------------------------------------
# CFG construction
# ---------------------------------------------------------------------------

def _edge_kinds(cfg):
    return {(e.src, e.dst, e.kind)
            for edges in cfg.succs.values() for e in edges}


def test_cfg_straight_line_reaches_exit():
    cfg = build_cfg(_func("def f(x):\n    y = x + 1\n    return y\n"))
    # entry -> assign -> return -> exit, and exc edges to the raise exit
    kinds = _edge_kinds(cfg)
    assert any(k == NORMAL and d == cfg.exit.nid for _, d, k in kinds)
    assert any(k == EXC and d == cfg.raise_exit.nid for _, d, k in kinds)


def test_cfg_if_has_true_false_edges_carrying_the_test():
    cfg = build_cfg(_func(
        "def f(x):\n"
        "    if x is None:\n"
        "        return 0\n"
        "    return 1\n"))
    branches = [n for n in cfg.nodes.values() if n.kind == "branch"]
    assert len(branches) == 1
    assert isinstance(branches[0].test, ast.Compare)
    out = {e.kind for e in cfg.succs[branches[0].nid]}
    assert TRUE in out and FALSE in out


def test_cfg_try_except_routes_body_exceptions_to_handler():
    cfg = build_cfg(_func(
        "def f(x):\n"
        "    try:\n"
        "        y = g(x)\n"
        "    except ValueError:\n"
        "        y = None\n"
        "    return y\n"))
    handler = [n for n in cfg.nodes.values()
               if isinstance(n.stmt, ast.ExceptHandler)]
    assert len(handler) == 1
    # the try-body statement has an exc edge INTO the handler entry
    assert any(e.kind == EXC and e.dst == handler[0].nid
               for edges in cfg.succs.values() for e in edges)
    # typed handler: the body keeps an escape edge to the raise exit too
    assert any(e.kind == EXC and e.dst == cfg.raise_exit.nid
               for edges in cfg.succs.values() for e in edges)


def test_cfg_catch_all_handler_stops_escape():
    cfg = build_cfg(_func(
        "def f(x):\n"
        "    try:\n"
        "        y = g(x)\n"
        "    except Exception:\n"
        "        y = None\n"
        "    return y\n"))
    body = [n for n in cfg.nodes.values()
            if n.stmt is not None and n.stmt.__class__ is ast.Assign
            and isinstance(n.stmt.value, ast.Call)]
    assert body, "fixture lost its try-body assign"
    for n in body:
        assert not any(e.kind == EXC and e.dst == cfg.raise_exit.nid
                       for e in cfg.succs[n.nid]), \
            "catch-all handler must absorb try-body exceptions"


def test_cfg_finally_covers_normal_and_exceptional_paths():
    cfg = build_cfg(_func(
        "def f(x):\n"
        "    try:\n"
        "        y = g(x)\n"
        "    finally:\n"
        "        release(x)\n"
        "    return y\n"))
    fin = [n for n in cfg.nodes.values()
           if n.stmt is not None and isinstance(n.stmt, ast.Expr)
           and isinstance(n.stmt.value, ast.Call)
           and getattr(n.stmt.value.func, "id", "") == "release"]
    assert len(fin) == 1
    # the finally body sits downstream of the try body AND feeds both
    # the after point (-> return -> exit) and the raise exit
    dsts = {(e.dst, e.kind) for e in cfg.succs[fin[0].nid]}
    assert any(d == cfg.raise_exit.nid for d, _ in dsts)
    assert any(d != cfg.raise_exit.nid and k == NORMAL for d, k in dsts)


def test_cfg_while_loop_has_back_edge_and_break_exit():
    cfg = build_cfg(_func(
        "def f(q):\n"
        "    while q:\n"
        "        v = q.pop()\n"
        "        if v < 0:\n"
        "            break\n"
        "    return q\n"))
    headers = [n for n in cfg.nodes.values()
               if n.kind == "branch" and isinstance(n.stmt, ast.While)]
    assert len(headers) == 1
    h = headers[0].nid
    assert any(e.dst == h for edges in cfg.succs.values()
               for e in edges if e.src != h), "no loop back edge"
    breaks = [n for n in cfg.nodes.values()
              if isinstance(n.stmt, ast.Break)]
    assert len(breaks) == 1
    ret = [n for n in cfg.nodes.values() if isinstance(n.stmt, ast.Return)]
    assert any(e.dst == ret[0].nid for e in cfg.succs[breaks[0].nid])


def test_cfg_with_block_keeps_exception_edges():
    cfg = build_cfg(_func(
        "def f(x):\n"
        "    with lock(x):\n"
        "        y = g(x)\n"
        "    return y\n"))
    # __exit__ suppression is not modeled: body exceptions escape
    assert any(e.kind == EXC and e.dst == cfg.raise_exit.nid
               for edges in cfg.succs.values() for e in edges)


def test_cfg_rejects_non_function():
    with pytest.raises(TypeError):
        build_cfg(ast.parse("x = 1").body[0])


# ---------------------------------------------------------------------------
# the worklist engine
# ---------------------------------------------------------------------------

class _ReachingTags(Analysis):
    """var -> frozenset of assigned constant tags (classic reaching
    definitions, small enough to eyeball)."""

    def join_values(self, a, b):
        return a | b

    def transfer(self, state, stmt):
        if isinstance(stmt, ast.Assign) \
                and isinstance(stmt.targets[0], ast.Name) \
                and isinstance(stmt.value, ast.Constant):
            out = dict(state)
            out[stmt.targets[0].id] = frozenset({stmt.value.value})
            return out
        return state


def test_fixpoint_joins_loop_states_and_terminates():
    cfg = build_cfg(_func(
        "def f(n):\n"
        "    x = 'a'\n"
        "    while n:\n"
        "        x = 'b'\n"
        "    return x\n"))
    states = analyze(cfg, _ReachingTags())
    # at the function exit both definitions reach (the loop may run 0+ times)
    assert states[cfg.exit.nid]["x"] == frozenset({"a", "b"})


def test_fixpoint_early_return_keeps_states_separate():
    cfg = build_cfg(_func(
        "def f(c):\n"
        "    x = 'a'\n"
        "    if c:\n"
        "        return x\n"
        "    x = 'b'\n"
        "    return x\n"))
    states = analyze(cfg, _ReachingTags())
    assert states[cfg.exit.nid]["x"] == frozenset({"a", "b"})
    rets = [n for n in cfg.nodes.values() if isinstance(n.stmt, ast.Return)]
    # the early return only ever sees the first definition
    early = min(rets, key=lambda n: n.stmt.lineno)
    assert states[early.nid]["x"] == frozenset({"a"})


def test_exc_edges_carry_pre_state():
    cfg = build_cfg(_func(
        "def f():\n"
        "    x = 'a'\n"
        "    y = g()\n"
        "    x = 'b'\n"
        "    return x\n"))
    states = analyze(cfg, _ReachingTags())
    # g() may raise before x was rebound: the raise exit still sees 'a'
    assert "a" in states[cfg.raise_exit.nid]["x"]
    assert states[cfg.exit.nid]["x"] == frozenset({"b"})


# ---------------------------------------------------------------------------
# slot-leak
# ---------------------------------------------------------------------------

STRANDED_SLOT = """
class Engine:
    def dispatch(self, model, req):
        slot = self.free_slots.popleft()
        run = self._build(model, req)        # raises -> slot stranded!
        self._slot[req.rid] = slot
        return run
"""

SAFE_FINALLY = """
class Engine:
    def dispatch(self, model, req):
        slot = self.free_slots.popleft()
        try:
            run = self._build(model, req)
        finally:
            self.free_slots.append(slot)
        return run

    def dispatch2(self, model, req):
        slot = self.free_slots.popleft()
        try:
            run = self._build(model, req)
        except Exception:
            self.free_slots.append(slot)
            raise
        self._slot[req.rid] = slot
        return run
"""

LEAKY_TYPED_HANDLER = """
class Engine:
    def dispatch(self, model, req):
        slot = self.free_slots.popleft()
        try:
            run = self._build(model, req)
        except RuntimeError:
            self.free_slots.append(slot)
            raise
        self._slot[req.rid] = slot
        return run
"""

SAFE_OWN_FIRST = """
class Engine:
    def dispatch(self, model, req):
        slot = self.free_slots.popleft()
        self._slot[req.rid] = slot           # owned before anything raises
        return self._build(model, req)
"""

GUARDED_MAYBE = """
class Engine:
    def _release(self, rid):
        slot = self._slot.pop(rid, None)
        if slot is None:
            return
        self.free_slots.append(slot)
"""

UNGUARDED_POOL_POP = """
class Engine:
    def steal(self):
        slot = self.free_slots.pop()
        self._audit(slot.id)                 # attribute access can raise...
        raise RuntimeError("stolen")         # ...and so does this
"""


def test_slot_leak_flags_acquire_then_raising_call(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", STRANDED_SLOT,
                "slot-leak")
    assert [f.checker for f in res.new] == ["slot-leak"]
    f = res.new[0]
    assert "escaping exception" in f.message
    assert "'slot'" in f.message
    # reported at the ACQUIRE site, where the fingerprint is stable
    assert "popleft" in f.snippet


def test_slot_leak_quiet_when_exception_path_releases(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", SAFE_FINALLY,
                "slot-leak")
    assert res.new == []


def test_slot_leak_typed_handler_leaves_an_escape_path(tmp_path):
    # `except RuntimeError` may not match: other exception types still
    # strand the slot — the path-sensitivity the syntactic rule lacked
    res = _lint(tmp_path, "repro/serving/custom.py", LEAKY_TYPED_HANDLER,
                "slot-leak")
    assert len(res.new) == 1
    assert "escaping exception" in res.new[0].message


def test_slot_leak_quiet_when_owned_before_raise(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", SAFE_OWN_FIRST,
                "slot-leak")
    assert res.new == []


def test_slot_leak_none_guard_narrows_maybe(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", GUARDED_MAYBE,
                "slot-leak")
    assert res.new == []


def test_slot_leak_flags_definitely_acquired_on_raise_path(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", UNGUARDED_POOL_POP,
                "slot-leak")
    assert len(res.new) == 1
    assert "escaping exception" in res.new[0].message


def test_slot_leak_scoped_to_serving(tmp_path):
    res = _lint(tmp_path, "repro/launch/custom.py", STRANDED_SLOT,
                "slot-leak")
    assert res.new == []


def test_slot_leak_real_serving_stack_is_clean():
    res = run_lint([REPO / "src" / "repro" / "serving"],
                   checkers=[c for c in ALL_CHECKERS
                             if c.name == "slot-leak"])
    assert res.new == [], "\n".join(str(f) for f in res.new)


# ---------------------------------------------------------------------------
# handle-lattice
# ---------------------------------------------------------------------------

def _handle_lint(tmp_path, body, rel="repro/serving/session.py"):
    return _lint(tmp_path, rel, body, "handle-lattice")


def test_lifecycle_table_is_self_validating():
    # the runtime depends on these invariants; the table checks itself
    assert set(lifecycle.FATES) <= lifecycle.TERMINAL
    assert lifecycle.RETRY_EDGE in lifecycle.EDGES
    for src, dst in lifecycle.EDGES:
        assert src not in lifecycle.TERMINAL


@pytest.mark.parametrize("fate", lifecycle.FATES)
def test_every_declared_fate_literal_is_legal(tmp_path, fate):
    res = _handle_lint(tmp_path,
                       f"def _expire(req):\n"
                       f"    req.fate = {fate!r}\n")
    assert res.new == []


def test_unknown_fate_literal_is_flagged(tmp_path):
    res = _handle_lint(tmp_path,
                       "def _expire(req):\n"
                       "    req.fate = 'vanished'\n")
    assert [f.checker for f in res.new] == ["handle-lattice"]
    assert "not a declared terminal disposition" in res.new[0].message


def test_fate_none_illegal_outside_init(tmp_path):
    res = _handle_lint(tmp_path,
                       "def resurrect(req):\n"
                       "    req.fate = None\n")
    assert len(res.new) == 1
    assert "absorbing" in res.new[0].message
    res2 = _handle_lint(tmp_path / "b",
                        "class Request:\n"
                        "    def __init__(self):\n"
                        "        self.fate = None\n")
    assert res2.new == []


def test_dynamic_fate_only_in_declared_funnel(tmp_path):
    body = ("def {name}(req, fate):\n"
            "    req.fate = fate\n")
    funnel = sorted(lifecycle.FATE_SETTER_FUNCTIONS)[0]
    assert _handle_lint(tmp_path / "a",
                        body.format(name=funnel)).new == []
    res = _handle_lint(tmp_path / "b", body.format(name="set_fate"))
    assert len(res.new) == 1
    assert "funnel" in res.new[0].message


def test_rollback_writes_only_in_retry_functions(tmp_path):
    retry = sorted(lifecycle.RETRY_FUNCTIONS)[0]
    body = ("def {name}(self, req):\n"
            "    req.t_first_issue = None\n"
            "    req.idx = 0\n"
            "    req._running = False\n")
    assert _handle_lint(tmp_path / "a",
                        body.format(name=retry)).new == []
    res = _handle_lint(tmp_path / "b", body.format(name="reset"))
    assert len(res.new) == 3
    assert all("backward edge" in f.message for f in res.new)


def test_rollback_literal_compared_by_repr_not_equality(tmp_path):
    # idx = False would pass a == comparison (False == 0); it must not
    # count as the declared rewind — but it must not crash either
    res = _handle_lint(tmp_path,
                       "def reset(self, req):\n"
                       "    req.idx = False\n")
    assert res.new == []


def test_absorbing_second_fate_on_same_path_flagged(tmp_path):
    res = _handle_lint(tmp_path,
                       "def sweep(self, req):\n"
                       "    req.fate = 'expired'\n"
                       "    self._log(req)\n"
                       "    req.fate = 'cancelled'\n")
    assert len(res.new) == 1
    assert "terminal -> terminal" in res.new[0].message


def test_absorbing_fates_on_disjoint_paths_are_fine(tmp_path):
    res = _handle_lint(tmp_path,
                       "def sweep(self, req, timed_out):\n"
                       "    if timed_out:\n"
                       "        req.fate = 'expired'\n"
                       "    else:\n"
                       "        req.fate = 'cancelled'\n")
    assert res.new == []


def test_handle_lattice_scoped_to_lifecycle_modules(tmp_path):
    res = _lint(tmp_path, "repro/serving/server.py",
                "def f(req):\n    req.fate = 'vanished'\n",
                "handle-lattice")
    assert res.new == []


# ---------------------------------------------------------------------------
# wallclock-taint
# ---------------------------------------------------------------------------

LAUNDER_HELPER = """
import time


def stamp():
    return time.perf_counter()
"""

LAUNDER_SINK = """
from repro.launch.helper import stamp


def schedule(queue):
    return stamp()
"""

AUDITED_HELPER = """
import time


def stamp():
    return time.perf_counter()  # reprolint: disable=wallclock-taint
"""

BARRIER_SINK = """
def advance(self, backend, model, sb, run):
    lat, toks = backend.execute_run(model, sb, run)
    return lat
"""


def test_taint_crosses_files_through_the_call_graph(tmp_path):
    helper = _write(tmp_path, "src/repro/launch/helper.py", LAUNDER_HELPER)
    sink = _write(tmp_path, "src/repro/core/sched.py", LAUNDER_SINK)
    res = _lint_project([helper, sink])
    assert [f.checker for f in res.new] == ["wallclock-taint"]
    f = res.new[0]
    assert f.path == "repro/core/sched.py"
    assert "launders wall time" in f.message
    assert "perf_counter" in f.message          # the witness chain


def test_suppressed_read_is_an_audited_boundary(tmp_path):
    helper = _write(tmp_path, "src/repro/launch/helper.py", AUDITED_HELPER)
    sink = _write(tmp_path, "src/repro/core/sched.py", LAUNDER_SINK)
    res = _lint_project([helper, sink])
    assert res.new == []


GATEWAY_CLOCK = """
import time


def pace():
    return time.perf_counter()
"""


def test_gateway_is_an_audited_wallclock_boundary(tmp_path):
    # serving/gateway/ is declared in WALLCLOCK_AUDITED_PREFIXES: an
    # UNSUPPRESSED clock read there neither reports nor seeds taint
    gw = _write(tmp_path, "src/repro/serving/gateway/pacer.py",
                GATEWAY_CLOCK)
    res = _lint_project([gw])
    assert res.new == []


def test_sim_path_module_still_fires_beside_audited_gateway(tmp_path):
    # the audit scope must not relax the sim path: the same unsuppressed
    # read in a virtual-time serving module fires even when an audited
    # gateway file sits in the same run
    gw = _write(tmp_path, "src/repro/serving/gateway/pacer.py",
                GATEWAY_CLOCK)
    sim = _write(tmp_path, "src/repro/serving/session.py",
                 "import time\n\n\ndef now():\n    return time.time()\n")
    res = _lint_project([gw, sim])
    assert [f.path for f in res.new] == ["repro/serving/session.py"]
    assert "virtual-time module" in res.new[0].message


def test_audited_gateway_read_does_not_taint_callers(tmp_path):
    # the whole-module audit has suppression semantics: a virtual-time
    # caller of a gateway clock-reading function inherits no taint
    gw = _write(tmp_path, "src/repro/serving/gateway/pacer.py",
                GATEWAY_CLOCK)
    sink = _write(tmp_path, "src/repro/core/sched.py",
                  "from repro.serving.gateway.pacer import pace\n\n\n"
                  "def schedule(queue):\n    return pace()\n")
    res = _lint_project([gw, sink])
    assert res.new == []


def test_direct_read_in_virtual_time_module_flagged(tmp_path):
    sink = _write(tmp_path, "src/repro/core/clocky.py",
                  "import time\n\n\ndef now():\n    return time.time()\n")
    res = _lint_project([sink])
    assert len(res.new) == 1
    assert "virtual-time module" in res.new[0].message


def test_backend_contract_calls_are_barriers(tmp_path):
    helper = _write(tmp_path, "src/repro/serving/jax_engine2.py",
                    "import time\n\n\n"
                    "class E:\n"
                    "    def execute_run(self, model, sb, run):\n"
                    "        t = time.perf_counter()\n"
                    "        return t, None\n")
    sink = _write(tmp_path, "src/repro/serving/session2.py", BARRIER_SINK)
    res = _lint_project([helper, sink])
    # the engine file is not virtual-time scope, the session call is a
    # barrier: no finding on either side
    assert res.new == []


def test_unrelated_same_name_function_does_not_taint(tmp_path):
    # a benchmark's run() reads the clock; an unimported module's run()
    # must not inherit the taint just by sharing the name
    bench = _write(tmp_path, "benchmarks/somebench.py",
                   "import time\n\n\ndef run():\n"
                   "    return time.perf_counter()\n")
    core = _write(tmp_path, "src/repro/core/other.py",
                  "def drive(policy):\n    return policy.run()\n")
    res = _lint_project([bench, core])
    assert res.new == []


def test_tests_are_callers_never_callees(tmp_path):
    # a test helper that reads the clock shares a production name; the
    # production caller must not be tainted through it
    t = _write(tmp_path, "tests/test_helper.py",
               "import time\n\n\ndef advance():\n"
               "    return time.perf_counter()\n")
    core = _write(tmp_path, "src/repro/core/other.py",
                  "def drive(sess):\n    return sess.advance()\n")
    res = _lint_project([t, core])
    assert res.new == []
