"""Training substrate: optimizer math, checkpointing, loss descent."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.training import (OptimizerConfig, adamw_update, checkpoint,
                            clip_by_global_norm, cosine_lr, global_norm,
                            init_adamw)


def test_adamw_single_step_matches_analytic():
    """One step from zero moments: delta = lr * (g/|g|... ) analytic check."""
    cfg = OptimizerConfig(lr=1e-2, betas=(0.9, 0.999), eps=1e-8,
                          weight_decay=0.0, grad_clip=1e9,
                          warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.array([1.0, -2.0], jnp.float32)}
    g = {"w": jnp.array([0.5, -0.25], jnp.float32)}
    state = init_adamw(p)
    new_p, new_state, m = adamw_update(cfg, p, g, state)
    # bias-corrected first step: mhat = g, vhat = g^2 -> delta = lr * sign(g)
    expect = p["w"] - cfg.lr * jnp.sign(g["w"])
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(expect),
                               rtol=1e-4)
    assert int(new_state.step) == 1


def test_weight_decay_only_on_matrices():
    cfg = OptimizerConfig(lr=1e-2, weight_decay=0.5, grad_clip=1e9,
                          warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.ones((2,), jnp.float32), "scale": jnp.ones((2,), jnp.float32)}
    g = {"w": jnp.zeros((2,)), "scale": jnp.zeros((2,))}
    new_p, _, _ = adamw_update(cfg, p, g, init_adamw(p))
    assert float(new_p["w"][0]) < 1.0          # decayed
    np.testing.assert_allclose(np.asarray(new_p["scale"]), 1.0)  # exempt


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.1
    assert abs(lrs[-1] - 0.1) < 0.05            # decayed to min ratio
    peak = int(np.argmax(lrs))
    assert all(lrs[i] >= lrs[i + 1] - 1e-9 for i in range(peak, len(lrs) - 1))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=8),
       st.floats(0.1, 10))
def test_clip_bounds_global_norm(vals, max_norm):
    g = {"x": jnp.asarray(vals, jnp.float32)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max_norm * (1 + 1e-3)
    if float(norm) <= max_norm:                 # no-op when under the bound
        np.testing.assert_allclose(np.asarray(clipped["x"]),
                                   np.asarray(g["x"], np.float32), rtol=1e-5)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        checkpoint.save(path, tree, step=7)
        restored, step = checkpoint.restore(path, tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

        bad = {"a": jnp.zeros((3, 2)), "b": {"c": jnp.ones((4,))}}
        with pytest.raises(ValueError):
            checkpoint.restore(path, bad)


def test_train_loop_reduces_loss():
    import dataclasses
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models.model import Model, RuntimeFlags
    from repro.training import train_loop

    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              d_model=64, vocab_size=256, d_ff=128)
    model = Model(cfg, RuntimeFlags(dtype=jnp.float32))
    data = TokenPipeline(DataConfig(vocab_size=256, seq_len=64, batch_size=4))
    opt = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    _, log = train_loop(model, opt, iter(data), 30, log_every=29,
                        verbose=False)
    assert log.losses[-1] < log.losses[0]
