"""The §Perf winning configurations must keep lowering+compiling
(regression guard for the hillclimb results recorded in EXPERIMENTS.md)."""
import subprocess
import sys

import pytest

_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import jax
from repro.launch.steps import lower_combo

small = dict(num_layers=2, d_model=256, d_ff=512, vocab_size=512)

# Target 1 winner: grouped decode on a kv-divisible serving mesh
mesh = jax.make_mesh((4, 8), ("data", "model"))
lowered, _ = lower_combo(
    "qwen2.5-32b", "decode_32k", mesh,
    cfg_overrides=dict(num_heads=8, num_kv_heads=8, head_dim=64, **small),
    flag_overrides={"use_scan": False, "grouped_decode": True},
    cache_prefer="kv", donate_cache=True)
lowered.compile()
print("QWEN-PERF-OK")

# Target 2 winner: sequence parallelism on MLA prefill
mesh = jax.make_mesh((4, 8), ("data", "model"))
lowered, _ = lower_combo(
    "minicpm3-4b", "prefill_32k", mesh,
    cfg_overrides=dict(num_heads=8, num_kv_heads=8, head_dim=64, **small),
    flag_overrides={"use_scan": False},
    rules_overrides={"act_seq": "model"})
lowered.compile()
print("MINICPM-PERF-OK")

# Target 3 winner: expert parallelism on a E-divisible mesh
mesh = jax.make_mesh((8, 4), ("data", "model"))
lowered, _ = lower_combo(
    "granite-moe-3b-a800m", "train_4k", mesh,
    cfg_overrides=dict(num_heads=8, num_kv_heads=4, head_dim=64, **small),
    flag_overrides={"use_scan": False},
    param_prefer={"w_gate": 0, "w_up": 0, "w_down": 0},
    rules_overrides={"experts": "model", "expert_ffn": None})
lowered.compile()
print("GRANITE-PERF-OK")
"""


@pytest.mark.slow
def test_perf_configs_lower():
    r = subprocess.run([sys.executable, "-c", _SNIPPET],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    for tag in ("QWEN-PERF-OK", "MINICPM-PERF-OK", "GRANITE-PERF-OK"):
        assert tag in r.stdout, (tag, r.stderr[-3000:])
