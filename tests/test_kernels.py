"""Per-kernel allclose validation against the pure-jnp oracles.

Every kernel is swept over shapes/dtypes and executed in interpret=True
mode (the kernel body runs in Python on CPU — the brief's validation
path for TPU-target Pallas kernels).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ragged decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,D,T", [
    (4, 8, 8, 64, 256),      # MHA
    (4, 8, 2, 64, 256),      # GQA 4:1
    (2, 16, 1, 128, 512),    # MQA, large D
    (3, 6, 3, 32, 128),      # odd sizes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_decode_attention(B, H, KV, D, T, dtype):
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, D), dtype)
    # ragged lengths incl. edge cases: 1, exactly one block, full T
    lengths = jnp.array(
        [1, T // 4 + 3, T // 2, T][:B] + [T // 3] * max(0, B - 4), jnp.int32)
    out = ops.ragged_decode_attention(q, k, v, lengths, block_t=64,
                                      interpret=True)
    expect = ref.ragged_decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_ragged_decode_blocksize_invariance():
    key = jax.random.key(1)
    ks = jax.random.split(key, 3)
    B, H, KV, D, T = 2, 4, 2, 64, 256
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, D), jnp.float32)
    lengths = jnp.array([100, 256], jnp.int32)
    outs = [ops.ragged_decode_attention(q, k, v, lengths, block_t=bt,
                                        interpret=True)
            for bt in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash prefill attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,D,window,q_offset", [
    (2, 256, 4, 64, None, 0),
    (2, 256, 4, 64, 64, 0),           # sliding window
    (1, 128, 2, 32, None, 128),       # catch-up chunk: q_offset > 0, T > S
    (2, 128, 8, 128, 96, 64),         # window + offset
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, D, window, q_offset, dtype):
    key = jax.random.key(2)
    ks = jax.random.split(key, 3)
    T = q_offset + S
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    out = ops.flash_attention(q, k, v, window=window, q_offset=q_offset,
                              block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window,
                                     q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_matches_model_chunked_attention():
    """Cross-check against the model's chunked attention (the serving path)."""
    from repro.models.layers import chunked_causal_attention
    key = jax.random.key(3)
    ks = jax.random.split(key, 3)
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    b = chunked_causal_attention(q, k, v, chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (2, 64, 256), (3, 5, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm(shape, dtype):
    key = jax.random.key(4)
    x = jax.random.normal(key, shape, dtype) * 3.0
    scale = jax.random.normal(jax.random.key(5), (shape[-1],), jnp.float32)
    out = ops.fused_rmsnorm(x, scale, interpret=True)
    expect = ref.fused_rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_fused_rmsnorm_matches_layer():
    from repro.models.layers import rms_norm
    x = jax.random.normal(jax.random.key(6), (4, 16, 128), jnp.float32)
    p = {"scale": jnp.full((128,), 1.5, jnp.float32)}
    a = ops.fused_rmsnorm(x, p["scale"], eps=1e-5, interpret=True)
    b = rms_norm(x, p, 1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,nh,hd,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
    (2, 64, 8, 16, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunked_pallas(B, S, nh, hd, N, chunk, dtype):
    key = jax.random.key(7)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    B_ssm = jax.random.normal(ks[3], (B, S, N), dtype)
    C_ssm = jax.random.normal(ks[4], (B, S, N), dtype)
    y, st = ops.ssd_chunked_pallas(x, dt, A, B_ssm, C_ssm, chunk,
                                   interpret=True)
    y_ref, st_ref = ref.ssd_chunked_ref(x, dt, A, B_ssm, C_ssm, chunk)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_pallas_chunk_invariance():
    """Different chunk sizes give the same sequence semantics."""
    key = jax.random.key(8)
    ks = jax.random.split(key, 5)
    B, S, nh, hd, N = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    B_ssm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    C_ssm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y32, st32 = ops.ssd_chunked_pallas(x, dt, A, B_ssm, C_ssm, 32,
                                       interpret=True)
    y64, st64 = ops.ssd_chunked_pallas(x, dt, A, B_ssm, C_ssm, 64,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st32), np.asarray(st64),
                               rtol=1e-4, atol=1e-4)
