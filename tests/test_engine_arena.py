"""KV-cache slot arena: lifecycle, parity with the legacy path, raggedness.

The arena engine must be a pure performance change: whatever slots requests
land in and however sub-batches merge, generated tokens must be IDENTICAL
to the seed per-request padded-cache (stack/unstack) path, which is kept
as ``cache_mode="legacy"`` exactly for this comparison.
"""
import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policies import LazyBatching
from repro.core.request import SubBatch
from repro.core.slack import SlackPredictor
from repro.kernels.ragged_decode_attn import ragged_decode_attention
from repro.serving.engine import JaxEngine
from repro.serving.npu_model import NPUPerfModel, TPU_V5E
from repro.serving.server import InferenceServer
from repro.serving.traffic import Trace
from repro.serving.workload import LengthDist, from_model_config


def _tiny(arch):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                               num_prefix_embeddings=0)


def _workload(cfg):
    return from_model_config(cfg,
                             prompt_dist=LengthDist((5, 7), (0.5, 0.5)),
                             decode_dist=LengthDist((2, 3), (0.5, 0.5)))


def _mk_req(wl, rng, prompt_len, decode_len):
    r = wl.sample_request(rng, 0.0)
    seq, prefix_len, cycle_len = wl.build_sequence(prompt_len, decode_len)
    r.sequence, r.prefix_len, r.cycle_len = seq, prefix_len, cycle_len
    r.prompt_len, r.decode_len = prompt_len, decode_len
    return r


def _run_nodes(engine, req, n_nodes=None):
    """Drive ``req`` alone for ``n_nodes`` nodes (all remaining if None)."""
    sb = SubBatch([req])
    steps = 0
    while not req.done and (n_nodes is None or steps < n_nodes):
        engine.execute("m", sb, req.next_node_id)
        sb.advance(0.0)
        steps += 1


def _serve(arch, mode, n=3, seed=0, fused=None):
    cfg = _tiny(arch)
    rng = np.random.default_rng(seed)
    wl = _workload(cfg)
    engine = JaxEngine(cfg, max_len=32, cache_mode=mode, n_slots=8,
                       fused=fused)
    reqs = []
    t = 0.0
    for _ in range(n):
        t += rng.exponential(0.05)
        r = wl.sample_request(rng, t)
        prompt = rng.integers(2, cfg.vocab_size, size=r.prompt_len)
        engine.register(r, prompt)
        reqs.append(r)
    pred = SlackPredictor.build([wl], NPUPerfModel(TPU_V5E), 60.0)
    stats = InferenceServer(LazyBatching(pred, max_batch=3), engine).run(
        Trace(reqs, t))
    assert len(stats.finished) == n
    return engine, reqs


# ---------------------------------------------------------------------------
# Parity: arena vs the seed padded-cache restacking path, token-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b",
                                  "recurrentgemma-9b"])
def test_arena_matches_legacy_generations(arch):
    eng_a, reqs_a = _serve(arch, "arena")
    eng_l, reqs_l = _serve(arch, "legacy")
    got = [eng_a.states[r.rid].generated for r in reqs_a]
    ref = [eng_l.states[r.rid].generated for r in reqs_l]
    assert got == ref, f"{arch}: {got} != {ref}"
    # every slot returned to the free list once serving drained
    assert eng_a.slots_in_use == 0


# ---------------------------------------------------------------------------
# Slot lifecycle across overlapping request lifetimes
# ---------------------------------------------------------------------------

def test_slot_assignment_release_and_reuse():
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(0)
    engine = JaxEngine(cfg, max_len=32, cache_mode="arena", n_slots=2)

    ra = _mk_req(wl, rng, 5, 2)
    rb = _mk_req(wl, rng, 5, 2)
    rc = _mk_req(wl, rng, 5, 2)
    for r in (ra, rb, rc):
        engine.register(r, rng.integers(2, cfg.vocab_size, size=r.prompt_len))

    # slots are lazy: registration alone holds nothing
    assert engine.slots_in_use == 0
    n_prefill = 1 + len(engine.kinds)            # emb + P-nodes
    _run_nodes(engine, ra, n_prefill)
    _run_nodes(engine, rb, n_prefill)
    slot_a, slot_b = engine.slot_of(ra), engine.slot_of(rb)
    assert engine.slots_in_use == 2 and slot_a != slot_b

    # arena full while A and B are both live
    with pytest.raises(RuntimeError, match="arena exhausted"):
        _run_nodes(engine, rc, n_prefill)

    # A finishing frees its slot; C then reuses it and generates fine
    _run_nodes(engine, ra)
    assert ra.done and engine.slots_in_use == 1
    rc2 = _mk_req(wl, rng, 5, 2)
    engine.register(rc2, rng.integers(2, cfg.vocab_size, size=rc2.prompt_len))
    _run_nodes(engine, rc2)
    assert rc2.done and engine.states[rc2.rid].generated
    # on_finished is idempotent with the in-execute release
    engine.on_finished("m", [ra, rc2])
    assert engine.slots_in_use == 1              # only B still live


def test_arena_auto_grows_when_n_slots_unpinned():
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(3)
    # pin a tiny 2-slot arena but keep auto-grow on, to exercise growth
    # cheaply (flat span storage: layer k's rows live at slot + k*n_slots)
    engine = JaxEngine(cfg, max_len=32, n_slots=2)
    engine._auto_grow = True

    reqs, prompts = [], []
    n_prefill = 1 + len(engine.kinds)
    for _ in range(3):                           # 3 concurrent > 2 slots
        r = _mk_req(wl, rng, 5, 2)
        p = rng.integers(2, cfg.vocab_size, size=5)
        engine.register(r, p)
        _run_nodes(engine, r, n_prefill)
        reqs.append(r)
        prompts.append(p)
    assert engine.n_slots == 4 and engine.slots_in_use == 3
    for r, p in zip(reqs, prompts):
        _run_nodes(engine, r)
        ref_engine = JaxEngine(cfg, max_len=32, n_slots=4)
        ref = _mk_req(wl, np.random.default_rng(9), 5, 2)
        ref_engine.register(ref, p)
        _run_nodes(ref_engine, ref)
        assert (engine.states[r.rid].generated
                == ref_engine.states[ref.rid].generated)


# ---------------------------------------------------------------------------
# Ragged merged decode: members at different pos
# ---------------------------------------------------------------------------

def test_ragged_merged_decode_matches_isolated():
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(1)
    engine = JaxEngine(cfg, max_len=32, cache_mode="arena", n_slots=4)

    r1 = _mk_req(wl, rng, 5, 3)
    r2 = _mk_req(wl, rng, 9, 2)
    p1 = rng.integers(2, cfg.vocab_size, size=5)
    p2 = rng.integers(2, cfg.vocab_size, size=9)
    engine.register(r1, p1)
    engine.register(r2, p2)

    n_prefill = 1 + len(engine.kinds)
    cycle = len(wl.cycle_ids())
    _run_nodes(engine, r1, n_prefill + cycle)     # prefill + 1 decode cycle
    _run_nodes(engine, r2, n_prefill)             # prefill only
    assert r1.next_node_id == r2.next_node_id == "D0"
    assert engine.states[r1.rid].pos != engine.states[r2.rid].pos

    # merged ragged decode until drained (finished members leave the batch)
    sb = SubBatch([r1, r2])
    while sb.size:
        engine.execute("m", sb, sb.node_id)
        sb.advance(0.0)
    got1 = engine.states[r1.rid].generated
    got2 = engine.states[r2.rid].generated

    for prompt, n_tok, got in ((p1, 3, got1), (p2, 2, got2)):
        ref_engine = JaxEngine(cfg, max_len=32, cache_mode="arena")
        ref = _mk_req(wl, np.random.default_rng(9), len(prompt), n_tok)
        ref_engine.register(ref, prompt)
        _run_nodes(ref_engine, ref)
        assert got == ref_engine.states[ref.rid].generated


# ---------------------------------------------------------------------------
# Engine-level Pallas arena path: slot-indexed kernel wired into merged
# ragged decode must reproduce the plain arena path (interpret mode on CPU)
# ---------------------------------------------------------------------------

def test_engine_pallas_arena_decode_matches_plain():
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    toks = {}
    for pallas in (False, True):
        rng = np.random.default_rng(2)
        engine = JaxEngine(cfg, max_len=32, cache_mode="arena", n_slots=4,
                           pallas=pallas)
        r1 = _mk_req(wl, rng, 5, 3)
        r2 = _mk_req(wl, rng, 7, 2)
        engine.register(r1, rng.integers(2, cfg.vocab_size, size=5))
        engine.register(r2, rng.integers(2, cfg.vocab_size, size=7))
        n_prefill = 1 + len(engine.kinds)
        _run_nodes(engine, r1, n_prefill + len(wl.cycle_ids()))
        _run_nodes(engine, r2, n_prefill)
        sb = SubBatch([r1, r2])             # merged, ragged pos
        while sb.size:
            engine.execute("m", sb, sb.node_id)
            sb.advance(0.0)
        toks[pallas] = [engine.states[r.rid].generated for r in (r1, r2)]
    assert toks[True] == toks[False]


# ---------------------------------------------------------------------------
# Run-commit contract: fused multi-node dispatch vs single-node dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-9b"])
def test_fused_runs_match_unfused_and_legacy(arch):
    """Server-driven serving with fused run dispatch must generate the
    exact tokens of per-node dispatch (same policy, same trace) — and the
    fused engine must actually have fused (fewer dispatched runs than
    nodes)."""
    eng_f, reqs_f = _serve(arch, "arena")                  # fused (default)
    eng_u, reqs_u = _serve(arch, "arena", fused=False)     # per-node arena
    eng_l, reqs_l = _serve(arch, "legacy")                 # seed numerics
    got = [eng_f.states[r.rid].generated for r in reqs_f]
    assert got == [eng_u.states[r.rid].generated for r in reqs_u]
    assert got == [eng_l.states[r.rid].generated for r in reqs_l]
    assert eng_f.runs_executed < eng_f.nodes_executed, \
        "no multi-node run was ever fused"
    assert eng_f.slots_in_use == 0


def test_merge_mid_run_takes_effect_at_run_boundary():
    """A merge candidate arriving while a run is committed must wait for
    the run boundary — and the resulting (later, ragged) merge must stay
    bit-exact vs the same schedule dispatched node-at-a-time."""
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(5)
    engine = JaxEngine(cfg, max_len=32, n_slots=8)
    r1 = _mk_req(wl, rng, 7, 3)
    r2 = _mk_req(wl, rng, 5, 2)
    p1 = rng.integers(2, cfg.vocab_size, size=7)
    p2 = rng.integers(2, cfg.vocab_size, size=5)
    engine.register(r1, p1)
    engine.register(r2, p2)

    # r1 commits prefill + its first decode cycle as one run; r2 "arrives"
    # mid-run and cannot join until the boundary
    sb1 = SubBatch([r1])
    run = sb1.run_nodes(stop_after={"head"})
    assert run[0] == "emb" and run[-1] == "head" and len(run) > 2
    engine.execute_run("m", sb1, run)
    sb1.advance_n(len(run), 0.0)

    # r2 catches up: its run stops BEFORE D0, where r1 is parked
    sb2 = SubBatch([r2])
    run2 = sb2.run_nodes(stop_before={"D0"})
    assert run2[-1] == f"P{len(engine.kinds) - 1}"
    engine.execute_run("m", sb2, run2)
    sb2.advance_n(len(run2), 0.0)

    # merge at the boundary: both at D0, ragged positions
    assert r1.next_node_id == r2.next_node_id == "D0"
    assert engine.states[r1.rid].pos != engine.states[r2.rid].pos
    sb = SubBatch([r1, r2])
    while sb.size:
        run = sb.run_nodes(stop_after={"head"})
        engine.execute_run("m", sb, run)
        sb.advance_n(len(run), 0.0)
    got = [engine.states[r.rid].generated for r in (r1, r2)]

    eng2 = JaxEngine(cfg, max_len=32, n_slots=8)
    rng2 = np.random.default_rng(5)
    q1 = _mk_req(wl, rng2, 7, 3)
    q2 = _mk_req(wl, rng2, 5, 2)
    eng2.register(q1, p1)
    eng2.register(q2, p2)
    n_prefill = 1 + len(eng2.kinds)
    _run_nodes(eng2, q1, n_prefill + len(wl.cycle_ids()))
    _run_nodes(eng2, q2, n_prefill)
    sb = SubBatch([q1, q2])
    while sb.size:
        eng2.execute("m", sb, sb.node_id)
        sb.advance(0.0)
    ref = [eng2.states[r.rid].generated for r in (q1, q2)]
    assert got == ref
    assert engine.slots_in_use == 0


def test_bucketed_prefill_pads_and_stays_bitexact():
    """Prompts whose prefill length is NOT a power of two exercise the
    length-bucket padding; a 3-member merge exercises batch-bucket padding
    (Bp=4 with one OOB-slot row). Tokens must equal isolated single-node
    generation."""
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(7)
    engine = JaxEngine(cfg, max_len=32, n_slots=8)
    lens = [6, 7, 10]                    # prefill 5, 6, 9 -> buckets 8, 8, 16
    reqs, prompts = [], []
    for pl in lens:
        r = _mk_req(wl, rng, pl, 2)
        p = rng.integers(2, cfg.vocab_size, size=pl)
        engine.register(r, p)
        reqs.append(r)
        prompts.append(p)
    sb = SubBatch(list(reqs))            # prefill all three together
    while sb.size:
        run = sb.run_nodes(stop_after={"head"})
        engine.execute_run("m", sb, run)
        sb.advance_n(len(run), 0.0)
    for r, p in zip(reqs, prompts):
        ref_engine = JaxEngine(cfg, max_len=32, n_slots=8)
        ref = _mk_req(wl, np.random.default_rng(9), len(p), 2)
        ref_engine.register(ref, p)
        _run_nodes(ref_engine, ref)
        assert (engine.states[r.rid].generated
                == ref_engine.states[ref.rid].generated)


def test_run_continuing_past_head_stays_bitexact():
    """A committed run shaped [..., head, D0..] (a stop_before node parks
    the batch mid-NEXT-cycle) decodes past its own head: the context
    bucket must cover the post-head position's freshly written K/V row."""
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(13)
    engine = JaxEngine(cfg, max_len=32, n_slots=4)
    r = _mk_req(wl, rng, 7, 3)
    p = rng.integers(2, cfg.vocab_size, size=7)
    engine.register(r, p)
    sb = SubBatch([r])
    run = sb.run_nodes(stop_before={"D0"})       # prefill
    engine.execute_run("m", sb, run)
    sb.advance_n(len(run), 0.0)
    run = sb.run_nodes(stop_before={"D1"})       # just D0
    assert run == ("D0",)
    engine.execute_run("m", sb, run)
    sb.advance_n(len(run), 0.0)
    while sb.size:                               # D1, head, D0 | D1, head...
        run = sb.run_nodes(stop_before={"D1"})
        assert run[0] == "D1"
        engine.execute_run("m", sb, run)
        sb.advance_n(len(run), 0.0)

    ref_engine = JaxEngine(cfg, max_len=32, n_slots=4)
    ref = _mk_req(wl, np.random.default_rng(9), 7, 3)
    ref_engine.register(ref, p)
    _run_nodes(ref_engine, ref)
    assert (engine.states[r.rid].generated
            == ref_engine.states[ref.rid].generated)


def test_parked_midcycle_batch_survives_other_batch_runs():
    """A sub-batch parked MID-cycle keeps its in-flight activations only in
    the engine's batched-x cache; another batch's fused cycle-start run
    must flush (not clobber) them, and the parked batch must resume
    bit-exact."""
    cfg = _tiny("llama3.2-1b")
    wl = _workload(cfg)
    rng = np.random.default_rng(11)
    engine = JaxEngine(cfg, max_len=32, n_slots=8)
    ra = _mk_req(wl, rng, 5, 2)
    rb = _mk_req(wl, rng, 7, 2)
    pa = rng.integers(2, cfg.vocab_size, size=5)
    pb = rng.integers(2, cfg.vocab_size, size=7)
    engine.register(ra, pa)
    engine.register(rb, pb)

    sba = SubBatch([ra])
    run = sba.run_nodes(stop_before={"D0"})      # A: prefill
    engine.execute_run("m", sba, run)
    sba.advance_n(len(run), 0.0)
    run = sba.run_nodes(stop_before={"head"})    # A: parked mid-cycle
    assert run[0] == "D0" and "head" not in run and len(run) > 1
    engine.execute_run("m", sba, run)
    sba.advance_n(len(run), 0.0)

    sbb = SubBatch([rb])                         # B: full runs meanwhile
    while sbb.size:
        run = sbb.run_nodes(stop_after={"head"})
        engine.execute_run("m", sbb, run)
        sbb.advance_n(len(run), 0.0)

    while sba.size:                              # A resumes mid-cycle
        run = sba.run_nodes(stop_after={"head"})
        engine.execute_run("m", sba, run)
        sba.advance_n(len(run), 0.0)

    for r, p in ((ra, pa), (rb, pb)):
        ref_engine = JaxEngine(cfg, max_len=32, n_slots=8)
        ref = _mk_req(wl, np.random.default_rng(9), len(p), 2)
        ref_engine.register(ref, p)
        _run_nodes(ref_engine, ref)
        assert (engine.states[r.rid].generated
                == ref_engine.states[ref.rid].generated)


# ---------------------------------------------------------------------------
# Pallas kernel slot indirection == explicit gather (interpret mode on CPU)
# ---------------------------------------------------------------------------

def test_ragged_kernel_slot_indirection():
    rng = np.random.default_rng(0)
    B, N, T, H, KV, D = 3, 6, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((N, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((N, T, KV, D)), jnp.float32)
    lengths = jnp.asarray([5, 17, 32], jnp.int32)
    slots = jnp.asarray([4, 0, 2], jnp.int32)
    out = ragged_decode_attention(q, k, v, lengths, slots=slots,
                                  interpret=True)
    ref = ragged_decode_attention(q, k[slots], v[slots], lengths,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
