"""Serving gateway suite: metrics primitives, fate mapping, live
HTTP/SSE exchanges over the sim backend (virtual time bridged to wall
pacing), backpressure/timeout middleware, SIGTERM drain through the
launcher, and cancellation-under-streaming on the real JAX engine
(client disconnect -> handle.cancel() -> zero slot leak, survivors
bit-exact)."""
import argparse
import asyncio
import dataclasses
import json
import math
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                       / "benchmarks"))
import loadgen

import repro.launch.gateway as launch_gateway
from repro.configs import get_config
from repro.core import lifecycle
from repro.core.policies import LazyBatching
from repro.core.slack import SlackPredictor
from repro.serving.gateway import (DEFAULT_BUCKETS, FATE_STATUS,
                                   Backpressure, GatewayApp,
                                   MetricsRegistry, status_for_state)
from repro.serving.gateway.prom import Histogram, Rolling
from repro.serving.npu_model import NPUPerfModel, PAPER_NPU, TPU_V5E
from repro.serving.session import HandleState, ServingSession
from repro.serving.workload import LengthDist, from_model_config

REPO = Path(__file__).resolve().parents[1]
HOST = "127.0.0.1"


def _args(**over):
    """A launch/gateway.py argument namespace with test defaults."""
    ns = argparse.Namespace(
        host=HOST, port=0, time_scale=200.0, tick_ms=1.0,
        request_timeout=None, max_inflight=None,
        metrics_log_interval=None, drain_grace=5.0, quiet=True,
        json_out=None, assert_no_leak=False, arch="transformer",
        models=None, arbiter="least-slack", policy="lazyb", engine="sim",
        sla=0.1, sla_tiers="gold:0.05,bulk:0.5", max_batch=64,
        window=0.025, mem_slots=48, mem_shares=None, fault_spec=None,
        fault_seed=None, max_retries=None, cancel_expired=False,
        max_queue=None, shed=False, shed_priorities=None, hw="paper",
        seed=0)
    for key, value in over.items():
        setattr(ns, key, value)
    return ns


async def _post(port, body, timeout=30.0):
    loop = asyncio.get_running_loop()
    return await asyncio.wait_for(
        loadgen.do_request(HOST, port, "/v1/generate", body, loop.time()),
        timeout=timeout)


# ---------------------------------------------------------------------------
# prom primitives
# ---------------------------------------------------------------------------

def test_registry_exposition_format():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help text", ("model",))
    g = reg.gauge("t_depth", "queue depth")
    c.inc(model="a")
    c.inc(2, model='we"ird\n')
    g.set(3)
    text = reg.expose()
    assert "# HELP t_total help text" in text
    assert "# TYPE t_total counter" in text
    assert 't_total{model="a"} 1' in text
    assert 't_total{model="we\\"ird\\n"} 2' in text      # label escaping
    assert "t_depth 3" in text
    with pytest.raises(ValueError):
        c.inc(-1, model="a")                             # counters only go up
    with pytest.raises(ValueError):
        c.inc(model="a", wrong="b")                      # undeclared label
    with pytest.raises(ValueError):
        reg.counter("t_total", "duplicate")


def test_counter_set_total_is_idempotent_and_monotone():
    reg = MetricsRegistry()
    c = reg.counter("runs_total", "h")
    c.set_total(5)
    c.set_total(5)                 # re-sampling the same value: no double count
    assert c.value() == 5
    c.set_total(3)                 # upstream can never go backwards
    assert c.value() == 5
    c.set_total(9)
    assert c.value() == 9


def test_histogram_cumulative_buckets():
    h = Histogram("lat", "h", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    rows = {f"{suffix}{labels}": val for suffix, labels, val in h.samples()}
    assert rows['_bucket{le="0.01"}'] == 1
    assert rows['_bucket{le="0.1"}'] == 2                # cumulative
    assert rows['_bucket{le="1"}'] == 3
    assert rows['_bucket{le="+Inf"}'] == 4
    assert rows["_count"] == 4
    assert abs(rows["_sum"] - 5.555) < 1e-9
    with pytest.raises(ValueError):
        Histogram("bad", "h", buckets=())


def test_rolling_window_mean_recovers():
    r = Rolling("att", "h", window=4)
    for v in (0, 0, 0, 0):
        r.observe(v)
    assert r.value() == 0.0
    for v in (1, 1, 1, 1):                    # overload clears: window slides
        r.observe(v)
    assert r.value() == 1.0
    assert math.isnan(Rolling("empty", "h").value())


def test_fate_status_covers_every_lifecycle_fate():
    # a new terminal fate in the lifecycle table must pick an HTTP status
    # (terminal = every state except the three in-service ones)
    terminal = set(lifecycle.STATES) - {"queued", "admitted", "running"}
    assert set(FATE_STATUS) == terminal
    assert status_for_state(HandleState.DONE) == 200
    assert status_for_state(HandleState.SHED) == 503


def test_serve_stats_gain_p95():
    args = _args()
    session = launch_gateway.build_session(args)
    rng = np.random.default_rng(0)
    wl = session.registry["transformer"].workload
    for i in range(40):
        session.submit(wl.sample_request(rng, i * 0.002))
    stats = session.drain()
    s = stats.summary(sla=0.1)
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    for row in stats.per_class(0.1).values():
        assert "p95_ms" in row
    for row in stats.per_model(0.1).values():
        assert "p95_ms" in row


# ---------------------------------------------------------------------------
# live gateway over the sim backend
# ---------------------------------------------------------------------------

def test_e2e_sim_streaming_metrics_and_drain():
    async def scenario():
        app = launch_gateway.build_app(_args())
        await app.start()
        results = await asyncio.gather(*[
            _post(app.port, {"model": "transformer",
                             "sla_class": "gold" if i % 2 else "bulk"})
            for i in range(12)])
        status, metrics = await loadgen.fetch(HOST, app.port, "/metrics")
        status_h, _ = await loadgen.fetch(HOST, app.port, "/healthz")
        status_r, _ = await loadgen.fetch(HOST, app.port, "/readyz")
        stats = await app.drain()
        return app, results, status, metrics.decode(), status_h, status_r, stats

    app, results, mstatus, metrics, hstatus, rstatus, stats = (
        asyncio.run(scenario()))
    assert hstatus == 200 and rstatus == 200
    for r in results:
        assert r["status"] == 200 and r["fate"] == "done"
        assert r["tokens"] > 0
        assert r["latency_s"] is not None and r["ttft_s"] is not None
        assert r["ttft_s"] <= r["latency_s"]
    assert len(stats.finished) == 12
    # /metrics exposes the acceptance families with live values
    assert mstatus == 200
    assert 'gateway_attainment{model="transformer",sla_class=' in metrics
    assert 'gateway_queue_depth{model="transformer"}' in metrics
    assert "gateway_arena_slots_total 48" in metrics
    assert "gateway_requests_total" in metrics
    assert "gateway_request_latency_seconds_bucket" in metrics
    # zero leaked slots after drain
    assert app.session.backend.memory_stats().slots_live == 0
    # structured access log: one http record per exchange, each with an id
    http_recs = [r for r in app.access_log.records if r["event"] == "http"]
    assert len(http_recs) == 12
    assert all(r["id"] and r["status"] == 200 and r["fate"] == "done"
               for r in http_recs)
    assert app.access_log.records[0]["event"] == "ready"
    assert app.access_log.records[-1]["event"] == "drain"


def test_bad_requests_get_400_and_unknown_route_404():
    async def scenario():
        app = launch_gateway.build_app(_args())
        await app.start()
        unknown_model = await _post(app.port, {"model": "nope"})
        bad_tier = await _post(app.port, {"model": "transformer",
                                          "sla_class": "platinum"})
        s404, _ = await loadgen.fetch(HOST, app.port, "/nope")
        s405, _ = await loadgen.fetch(HOST, app.port, "/v1/generate")
        await app.drain()
        return unknown_model, bad_tier, s404, s405

    unknown_model, bad_tier, s404, s405 = asyncio.run(scenario())
    assert unknown_model["status"] == 400
    assert bad_tier["status"] == 400
    assert s404 == 404
    assert s405 == 405


def test_rejected_at_admission_maps_to_422():
    async def scenario():
        args = _args()
        session = launch_gateway.build_session(args)
        session.reject_infeasible = True
        app = GatewayApp(session, port=0, time_scale=200.0, tick=0.001,
                         default_sla=0.1,
                         deadline_by_class={"impossible": 1e-9},
                         log_enabled=False)
        await app.start()
        r = await _post(app.port, {"model": "transformer",
                                   "sla_class": "impossible"})
        ok = await _post(app.port, {"model": "transformer"})
        await app.drain()
        return r, ok

    r, ok = asyncio.run(scenario())
    assert r["status"] == 422 and r["fate"] == "rejected"
    assert ok["status"] == 200 and ok["fate"] == "done"


def test_backpressure_429_with_retry_after_when_queue_full():
    async def scenario():
        # admission is memory-gated: with a single KV slot the first
        # (long) request is admitted and holds the slot for ~1s of wall
        # time at this scale, the second parks in the policy queue, and
        # max_queue=1 saturates the ingress budget — the gateway
        # refuses the third at the door.  time_scale is small but NOT
        # frozen: a dispatched run advances the session clock past the
        # wall target by its own latency, and the pump must be able to
        # catch up before the second arrival can enter the queue.
        app = launch_gateway.build_app(
            _args(time_scale=0.01, max_queue=1, mem_slots=1))
        await app.start()
        body = {"model": "transformer", "prompt_len": 32,
                "decode_len": 256}
        pending = [asyncio.create_task(_post(app.port, dict(body)))]
        await asyncio.sleep(0.3)             # admitted + first run done
        pending.append(asyncio.create_task(_post(app.port, dict(body))))
        await asyncio.sleep(0.3)             # parked in the policy queue
        third = await _post(app.port, dict(body))
        await app.drain()                    # fast-forwards: 1 & 2 complete
        return await asyncio.gather(*pending), third, app

    pending, third, app = asyncio.run(scenario())
    assert third["status"] == 429
    assert third["retry_after"] > 0
    assert all(r["status"] == 200 and r["fate"] == "done" for r in pending)
    assert app.metrics.backpressure.total() == 1
    assert app.session.backend.memory_stats().slots_live == 0


def test_request_timeout_408_cancels_and_frees():
    async def scenario():
        app = launch_gateway.build_app(
            _args(time_scale=1e-9, request_timeout=0.25))
        await app.start()
        r = await _post(app.port, {"model": "transformer"})
        handles = list(app.session.handles.values())
        stats = await app.drain()
        return r, handles, stats, app

    r, handles, stats, app = asyncio.run(scenario())
    assert r["status"] == 408
    assert len(handles) == 1
    assert handles[0].state is HandleState.CANCELLED
    assert len(stats.cancelled_requests) == 1
    assert not stats.finished
    assert app.session.backend.memory_stats().slots_live == 0


def test_inflight_bound_respects_protected_headroom():
    class StubRegistry:
        @staticmethod
        def entries():
            return []

    class StubSession:
        registry = StubRegistry()
        max_queue = None
        memory_aware = False

    class StubDriver:
        session = StubSession()
        inflight = 4

        @staticmethod
        def protected_priority():
            return 1

        @staticmethod
        def completion_rate():
            return 10.0

        @staticmethod
        def mem_room(model):
            return None

    bp = Backpressure(StubDriver(), max_inflight=4, headroom=2)
    # bulk (below protected priority): refused at the soft bound, with a
    # backlog/throughput Retry-After hint
    hint = bp.check("m", shed_priority=0)
    assert hint is not None and abs(hint - 4 / 10.0) < 1e-9
    # protected tier rides the headroom past the soft bound
    assert bp.check("m", shed_priority=1) is None
    StubDriver.inflight = 6                  # headroom exhausted too
    assert bp.check("m", shed_priority=1) is not None


def test_draining_gateway_refuses_new_work_503():
    async def scenario():
        app = launch_gateway.build_app(_args())
        await app.start()
        port = app.port
        await app.drain()
        # the listener is closed after drain; readyz flipped before that
        try:
            r = await _post(port, {"model": "transformer"}, timeout=2.0)
            return r["status"]
        except (ConnectionError, OSError, asyncio.TimeoutError):
            return "refused"

    assert asyncio.run(scenario()) in ("refused", 503)


# ---------------------------------------------------------------------------
# launcher subprocess: SIGTERM drain, exit code, artifact
# ---------------------------------------------------------------------------

def test_launcher_sigterm_drains_cleanly(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    json_out = tmp_path / "gw.json"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.gateway", "--port", "0",
         "--time-scale", "200", "--sla-tiers", "gold:0.05,bulk:0.5",
         "--mem-slots", "32", "--assert-no-leak",
         "--json-out", str(json_out)],
        env=env, stderr=subprocess.PIPE, text=True)
    try:
        port = None
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            line = proc.stderr.readline()
            if not line:
                break
            try:
                record = json.loads(line)
            except ValueError:
                continue
            if record.get("event") == "ready":
                port = record["port"]
                break
        assert port is not None, "gateway never logged ready"

        async def drive():
            return await asyncio.gather(*[
                _post(port, {"sla_class": "gold" if i % 2 else "bulk"})
                for i in range(6)])

        results = asyncio.run(drive())
        assert all(r["status"] == 200 for r in results)
        proc.send_signal(signal.SIGTERM)
        code = proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)
    assert code == 0                      # clean drain + no leaked slots
    doc = json.loads(json_out.read_text())
    assert doc["summary"]["completed"] == 6
    assert doc["memory"]["slots_live"] == 0
    assert "gateway" in " ".join(doc["invocation"]["argv"])
    assert doc["invocation"]["seed"] == 0
    assert "p95_ms" in doc["summary"]


# ---------------------------------------------------------------------------
# cancellation under streaming (real JAX engine)
# ---------------------------------------------------------------------------

class _SlowRuns:
    """Wall-delay every run: the tiny engine decodes a whole request
    inside one pump tick, so a client abort could never beat the final
    run boundary — the delay opens a real window between boundaries for
    the disconnect -> cancel path to land deterministically."""

    def __init__(self, inner, delay_s=0.05):
        self._inner, self._delay = inner, delay_s

    def execute_run(self, model, sb, node_ids):
        time.sleep(self._delay)
        return self._inner.execute_run(model, sb, node_ids)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _jax_app():
    from repro.serving.engine import JaxEngine

    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              d_model=64, d_ff=128, vocab_size=128,
                              num_prefix_embeddings=0)
    wl = from_model_config(cfg, prompt_dist=LengthDist((6,), (1.0,)),
                           decode_dist=LengthDist((8,), (1.0,)))
    engine = JaxEngine(cfg, max_len=32, n_slots=4)
    pred = SlackPredictor.build([wl], NPUPerfModel(TPU_V5E), 60.0)
    session = ServingSession(backend=_SlowRuns(engine), seed=9)
    session.register(wl.name, wl,
                     policy=LazyBatching(pred, max_batch=4))
    return GatewayApp(session, port=0, time_scale=1.0, tick=0.002,
                      default_sla=60.0, log_enabled=False), engine


async def _stream_one(port, i, disconnect_after=None, decode_len=8):
    """One raw SSE exchange; abort the connection after
    ``disconnect_after`` token events when set."""
    reader, writer = await asyncio.open_connection(HOST, port)
    body = json.dumps({"prompt_len": 6,
                       "decode_len": decode_len}).encode()
    writer.write((f"POST /v1/generate HTTP/1.1\r\nhost: {HOST}\r\n"
                  f"content-type: application/json\r\n"
                  f"content-length: {len(body)}\r\n"
                  f"connection: close\r\n\r\n").encode() + body)
    await writer.drain()
    await loadgen._read_headers(reader)
    tokens, fate = [], None
    async for event, data in loadgen._sse_events(reader):
        if event == "token":
            tokens.append(data["token"])
            if disconnect_after is not None and len(tokens) >= disconnect_after:
                writer.transport.abort()     # vanish mid-stream
                return tokens, "aborted"
        elif event in ("done", "error"):
            fate = data.get("fate", event)
    writer.close()
    return tokens, fate


async def _jax_scenario(disconnect_idx):
    app, engine = _jax_app()
    await app.start()
    results = [None] * 4
    tasks = []
    for i in range(4):
        submitted = len(app.session.handles)

        async def one(i=i):
            # stream 1 decodes much longer than the rest (in the control
            # run too): disconnect detection needs a failed SSE write —
            # at least one run boundary after the abort — so the victim
            # must have plenty of decode left when the cancel lands
            results[i] = await _stream_one(
                app.port, i,
                disconnect_after=1 if i == disconnect_idx else None,
                decode_len=20 if i == 1 else 8)

        tasks.append(asyncio.create_task(one()))
        # serialize SUBMISSION order (prompt RNG draws happen at submit)
        # without serializing the streams themselves
        deadline = asyncio.get_running_loop().time() + 30
        while (len(app.session.handles) == submitted
               and asyncio.get_running_loop().time() < deadline):
            await asyncio.sleep(0.005)
    await asyncio.gather(*tasks)
    if disconnect_idx is not None:
        # the disconnect must reach CANCELLED (slot freed) before drain
        deadline = asyncio.get_running_loop().time() + 30
        handle = list(app.session.handles.values())[disconnect_idx]
        while (not handle.done
               and asyncio.get_running_loop().time() < deadline):
            await asyncio.sleep(0.005)
    stats = await app.drain()
    return results, stats, app, engine


def test_jax_client_disconnect_cancels_and_survivors_bit_exact():
    results, stats, app, engine = asyncio.run(_jax_scenario(1))
    ref_results, ref_stats, _, ref_engine = asyncio.run(_jax_scenario(None))

    handles = list(app.session.handles.values())
    assert handles[1].state is HandleState.CANCELLED
    assert len(stats.cancelled_requests) == 1
    assert len(stats.finished) == 3
    assert len(ref_stats.finished) == 4
    # zero-leak: the aborted stream's slot came back to the pool
    assert engine.slots_in_use == 0
    assert app.session.backend.memory_stats().slots_live == 0
    # surviving streams are BIT-EXACT vs the no-disconnect control run
    for i in (0, 2, 3):
        tokens, fate = results[i]
        ref_tokens, ref_fate = ref_results[i]
        assert fate == "done" and ref_fate == "done"
        assert len(tokens) == 8
        assert tokens == ref_tokens
    # the aborted stream saw its first token before vanishing
    assert results[1][1] == "aborted" and len(results[1][0]) >= 1
    assert results[1][0] == ref_results[1][0][:len(results[1][0])]
