"""Failure model: cancellation, expiry, seeded faults, retry, shedding.

Simulator cases exercise the session-level machinery (terminal handle
states, retry/backoff bookkeeping, bounded-ingress + brownout shedding,
the drain liveness guard); JAX cases prove the device-side contract —
a faulted run's retry replays prefill and regenerates tokens BIT-EXACT
vs a fault-free run, with the slot pool an exact partition afterwards.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LazyBatching, Serial, SLAClass, SlackPredictor)
from repro.core.request import Request
from repro.serving import (BrownoutConfig, FaultInjectingBackend, FaultSpec,
                           HandleState, NPUPerfModel, PAPER_NPU, RetryPolicy,
                           ServingSession, SimExecutor, TransientBackendError,
                           get_workload, parse_fault_spec, parse_fault_specs)

PERF = NPUPerfModel(PAPER_NPU)
MS = 1e-3


def lazyb(wl, sla=0.1, max_batch=16):
    return LazyBatching(SlackPredictor.build([wl], PERF, sla),
                        max_batch=max_batch)


def _submit_n(session, wl, n, rng, arrival=0.0, sla=None):
    handles = []
    for _ in range(n):
        r = wl.sample_request(rng, arrival)
        if sla is not None:
            r.sla = sla
        handles.append(session.submit(r))
    return handles


# ---------------------------------------------------------------------------
# FaultSpec parsing and validation
# ---------------------------------------------------------------------------

def test_fault_spec_parses_all_kinds():
    spec = parse_fault_spec("transient:0.05,oom:0.01,straggler:0.1x8,"
                            "latency:0.002")
    assert spec == FaultSpec(p_transient=0.05, p_oom=0.01, p_straggler=0.1,
                             straggler_factor=8.0, fault_latency=0.002)
    assert parse_fault_spec("straggler:0.2").straggler_factor == 4.0


def test_fault_spec_per_model_and_validation():
    specs = parse_fault_specs("bulk=transient:0.1;gold=straggler:0.02x6")
    assert set(specs) == {"bulk", "gold"}
    assert specs["bulk"].p_transient == 0.1
    assert specs["gold"].straggler_factor == 6.0
    assert isinstance(parse_fault_specs("transient:0.1"), FaultSpec)
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault_spec("cosmic:0.1")
    with pytest.raises(ValueError, match="sum"):
        FaultSpec(p_transient=0.7, p_oom=0.4)
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        BrownoutConfig(floor=0.0)


def test_fault_injection_is_seed_deterministic():
    """Two identically seeded wrapped backends inject byte-identical
    fault sequences regardless of instance identity."""
    spec = FaultSpec(p_transient=0.3, p_oom=0.1, p_straggler=0.2)
    wl = get_workload("transformer")

    def run(seed):
        backend = FaultInjectingBackend(SimExecutor(PERF), spec, seed=seed)
        session = ServingSession(lazyb(wl), backend, seed=7,
                                 retry=RetryPolicy(max_retries=2,
                                                   backoff_base=1e-4))
        _submit_n(session, wl, 12, np.random.default_rng(3))
        session.drain()
        return backend.fault_stats()

    a, b, c = run(11), run(11), run(12)
    assert a == b
    assert a != c                      # a different seed faults differently
    per = a["default"]
    assert per["draws"] > 0


# ---------------------------------------------------------------------------
# Retry with backoff
# ---------------------------------------------------------------------------

class _FaultNth(SimExecutor):
    """Deterministically raise a retryable fault on chosen dispatches."""

    def __init__(self, perf, fault_on=(), latency=0.0, **kw):
        super().__init__(perf, **kw)
        self.fault_on = set(fault_on)
        self.latency = latency
        self.dispatch = 0

    def execute_run(self, model, sb, node_ids):
        self.dispatch += 1
        if self.dispatch in self.fault_on:
            raise TransientBackendError(
                f"injected on dispatch {self.dispatch}",
                latency=self.latency)
        return super().execute_run(model, sb, node_ids)


def test_transient_faults_retry_to_completion():
    wl = get_workload("transformer")
    backend = _FaultNth(PERF, fault_on={2, 7})
    session = ServingSession(lazyb(wl, sla=1.0), backend, seed=1,
                             retry=RetryPolicy(max_retries=10,
                                               backoff_base=0.1 * MS))
    handles = _submit_n(session, wl, 4, np.random.default_rng(2))
    stats = session.drain()
    assert session.log.faults == 2
    assert all(h.state is HandleState.DONE for h in handles)
    assert len(stats.finished) == 4
    assert stats.retried == session.retried > 0
    assert any(h.retries > 0 for h in handles)
    # SLA accounting: everything finished, judged against ORIGINAL arrival
    assert stats.summary(sla=1.0)["retried"] == stats.retried
    # no simulated residency leaked across the fault/retry cycle
    assert backend.memory_stats().slots_live == 0


def test_fault_latency_burns_device_time_without_committing_nodes():
    wl = get_workload("transformer")
    backend = _FaultNth(PERF, fault_on={1}, latency=2 * MS)
    session = ServingSession(lazyb(wl, sla=1.0), backend, seed=1,
                             retry=RetryPolicy(max_retries=3,
                                               backoff_base=0.1 * MS))
    (h,) = _submit_n(session, wl, 1, np.random.default_rng(2))
    session.drain()
    assert h.state is HandleState.DONE
    # the faulted dispatch's detection latency is in busy_time, but its
    # nodes were never committed (node_lat only has the re-run's entries)
    assert session.log.busy_time > sum(
        nl.total for nl in session.log.node_lat.values()) + 1.9 * MS
    assert session.log.faults == 1


def test_retry_exhaustion_turns_failed_and_counts_as_violation():
    wl = get_workload("transformer")
    backend = FaultInjectingBackend(SimExecutor(PERF),
                                    FaultSpec(p_transient=1.0), seed=0)
    session = ServingSession(lazyb(wl), backend,
                             retry=RetryPolicy(max_retries=2,
                                               backoff_base=0.1 * MS))
    (h,) = _submit_n(session, wl, 1, np.random.default_rng(0))
    stats = session.drain()
    assert h.state is HandleState.FAILED
    assert h.done and h.retries == 2
    assert stats.failed_requests and not stats.finished
    # a failed request is a violation of its own deadline
    assert stats.sla_violation_rate(0.1) == 1.0
    assert stats.attainment(0.1) == 0.0
    # exhaustion released everything: no residency, no scheduler state
    assert session.policy.outstanding == 0
    assert backend.memory_stats().slots_live == 0


def test_without_retry_policy_backend_errors_propagate():
    """No RetryPolicy => the failure model is OFF: a dispatch fault
    raises out of drain() instead of being absorbed, so an engine's own
    capacity errors stay loud unless the caller opted in."""
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl), _FaultNth(PERF, fault_on=(1,)))
    _submit_n(session, wl, 2, np.random.default_rng(2))
    with pytest.raises(TransientBackendError):
        session.drain()


def test_non_retryable_fault_fails_immediately():
    wl = get_workload("transformer")

    class OneShotFatal(SimExecutor):
        def __init__(self, perf):
            super().__init__(perf)
            self.tripped = False

        def execute_run(self, model, sb, node_ids):
            if not self.tripped:
                self.tripped = True
                raise TransientBackendError("wedged", retryable=False)
            return super().execute_run(model, sb, node_ids)

    session = ServingSession(lazyb(wl), OneShotFatal(PERF),
                             retry=RetryPolicy())
    h1, h2 = _submit_n(session, wl, 2, np.random.default_rng(1))
    stats = session.drain()
    states = {h1.state, h2.state}
    assert HandleState.FAILED in states       # the faulted batch died...
    assert session.retried == 0               # ...without burning retries
    assert len(stats.failed_requests) == 2    # (both rode the same batch)


# ---------------------------------------------------------------------------
# Cancellation and expiry
# ---------------------------------------------------------------------------

def test_cancel_queued_and_midflight_leaves_survivors_alone():
    wl = get_workload("transformer")
    backend = SimExecutor(PERF, max_slots=16)
    session = ServingSession(lazyb(wl), backend)
    handles = _submit_n(session, wl, 4, np.random.default_rng(6))
    assert handles[0].cancel()                  # cancel while QUEUED
    assert handles[0].state is HandleState.CANCELLED
    assert not handles[0].cancel()              # idempotent: already dead
    session.step()                              # admit + first run
    victim = next(h for h in handles[1:]
                  if h.state in (HandleState.ADMITTED, HandleState.RUNNING))
    assert victim.cancel()                      # cancel mid-flight
    assert victim.state is HandleState.CANCELLED
    assert backend.memory_stats().slots_live <= 2   # slot freed eagerly
    stats = session.drain()
    survivors = [h for h in handles if h not in (handles[0], victim)]
    assert all(h.state is HandleState.DONE for h in survivors)
    assert len(stats.finished) == 2
    assert len(stats.cancelled_requests) == 2
    assert session.policy.outstanding == 0
    assert backend.memory_stats().slots_live == 0
    # cancelled handles can be released like any other terminal handle
    session.release(victim)
    assert victim.request.rid not in session.handles


def test_cancel_expired_reaps_provably_blown_deadlines():
    """Under cancel_expired, a request whose deadline passed mid-queue
    goes terminal EXPIRED at the next run boundary instead of burning
    batch capacity on a guaranteed violation."""
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl, sla=10.0), SimExecutor(PERF),
                             cancel_expired=True)
    rng = np.random.default_rng(8)
    doomed = wl.sample_request(rng, 0.0)
    doomed.sla = SLAClass("tight", 1e-6)        # provably unmeetable
    hd = session.submit(doomed)
    ok = _submit_n(session, wl, 3, rng)
    stats = session.drain()
    assert hd.state is HandleState.EXPIRED
    assert all(h.state is HandleState.DONE for h in ok)
    assert len(stats.expired_requests) == 1
    assert len(stats.finished) == 3
    # expiry is a violation of the victim's own class deadline
    assert stats.per_class(sla=10.0)["tight"]["expired"] == 1
    assert stats.per_class(sla=10.0)["tight"]["sla_violation_rate"] == 1.0


def test_without_cancel_expired_nothing_is_dropped():
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl, sla=10.0), SimExecutor(PERF))
    rng = np.random.default_rng(8)
    doomed = wl.sample_request(rng, 0.0)
    doomed.sla = SLAClass("tight", 1e-6)
    hd = session.submit(doomed)
    session.drain()
    assert hd.state is HandleState.DONE         # late, but served


# ---------------------------------------------------------------------------
# Load shedding: bounded ingress + brownout
# ---------------------------------------------------------------------------

def test_bounded_ingress_sheds_lowest_tier_loosest_deadline():
    wl_a, wl_b = get_workload("transformer"), get_workload("resnet")
    session = ServingSession(backend=SimExecutor(PERF), max_queue=3)
    session.register("gold", wl_a, policy=lazyb(wl_a), shed_priority=1)
    session.register("bulk", wl_b, policy=lazyb(wl_b), shed_priority=0)
    rng = np.random.default_rng(9)
    hb = [session.submit(wl_b.sample_request(rng, 0.0), model="bulk")
          for _ in range(3)]
    hg = [session.submit(wl_a.sample_request(rng, 0.0), model="gold")
          for _ in range(3)]
    stats = session.drain()
    # gold never sheds while a lower tier is available to victimize
    assert all(h.state is HandleState.DONE for h in hg)
    assert sum(h.state is HandleState.SHED for h in hb) == 3
    assert len(stats.shed_requests) == 3
    assert stats.per_model()["bulk"]["shed"] == 3


def test_brownout_sheds_lower_tier_when_protected_attainment_dips():
    wl_a, wl_b = get_workload("transformer"), get_workload("resnet")
    session = ServingSession(
        backend=SimExecutor(PERF),
        brownout=BrownoutConfig(floor=0.9, window=8, min_samples=2))
    session.register("gold", wl_a, policy=lazyb(wl_a, sla=10.0),
                     shed_priority=1)
    session.register("bulk", wl_b, policy=lazyb(wl_b, sla=10.0),
                     shed_priority=0)
    rng = np.random.default_rng(10)
    # gold requests with unmeetable deadlines: every finish is a miss
    hg = []
    for _ in range(4):
        r = wl_a.sample_request(rng, 0.0)
        r.sla = SLAClass("tight", 1e-6)
        hg.append(session.submit(r, model="gold"))
    # bulk arrives later, after the protected tier's attainment collapsed
    hb = [session.submit(wl_b.sample_request(rng, 1.0), model="bulk")
          for _ in range(4)]
    stats = session.drain()
    assert session.brownouts == 1
    assert all(h.state is HandleState.DONE for h in hg)
    assert all(h.state is HandleState.SHED for h in hb)
    assert len(stats.shed_requests) == 4


def test_single_tier_brownout_never_engages():
    wl = get_workload("transformer")
    session = ServingSession(
        lazyb(wl, sla=10.0), SimExecutor(PERF),
        brownout=BrownoutConfig(floor=0.9, window=8, min_samples=2))
    rng = np.random.default_rng(11)
    handles = _submit_n(session, wl, 6, rng, sla=SLAClass("tight", 1e-6))
    session.drain()
    # attainment collapses, brownout activates — but with one priority
    # level there is nothing lower-tier to shed: no work is dropped
    assert all(h.state is HandleState.DONE for h in handles)


# ---------------------------------------------------------------------------
# drain() liveness guard
# ---------------------------------------------------------------------------

def test_drain_raises_on_livelock_with_diagnostics():
    class WedgedPolicy(Serial):
        """Queues work it never offers, with a timer stuck at t=0: every
        step 'progresses' to the same instant forever."""
        def next_work(self, now):
            return None

        def next_timer(self, now):
            return 0.0

    wl = get_workload("transformer")
    session = ServingSession(WedgedPolicy(), SimExecutor(PERF))
    session.submit(wl.sample_request(np.random.default_rng(0), 0.0))
    with pytest.raises(RuntimeError, match="livelock") as ei:
        session.drain(stall_limit=50)
    assert "backlog" in str(ei.value)
    assert "queued" in str(ei.value)


def test_drain_with_faults_still_terminates():
    wl = get_workload("transformer")
    backend = FaultInjectingBackend(SimExecutor(PERF),
                                    FaultSpec(p_transient=0.4), seed=3)
    session = ServingSession(lazyb(wl), backend,
                             retry=RetryPolicy(max_retries=3))
    _submit_n(session, wl, 8, np.random.default_rng(4))
    stats = session.drain()          # must not trip the liveness guard
    assert len(stats.finished) + len(stats.failed_requests) == 8


# ---------------------------------------------------------------------------
# JAX engine: retry is bit-exact, slot pool stays a partition
# ---------------------------------------------------------------------------

def _tiny():
    cfg = get_config("llama3.2-1b").reduced()
    return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                               num_prefix_embeddings=0)


def test_jax_retry_regenerates_tokens_bit_exact_no_slot_leak():
    """Transient faults over the real engine: every request completes,
    retried requests replay prefill and regenerate the SAME tokens as a
    fault-free run (same session seed => same prompts), and the arena
    free pool is an exact partition of slots afterwards."""
    from repro.serving.engine import JaxEngine
    from test_engine_memory import _pool_consistent, _workload

    cfg = _tiny()
    wl = _workload(cfg)
    perf = NPUPerfModel(PAPER_NPU)

    def serve(spec):
        engine = JaxEngine(cfg, max_len=32, n_slots=4)
        backend = (engine if spec is None
                   else FaultInjectingBackend(engine, spec, seed=21))
        pol = LazyBatching(SlackPredictor.build([wl], perf, 60.0),
                           max_batch=4)
        # generous budget: with p=0.1 and a handful of dispatches per
        # pass, exhaustion probability is ~1e-15 — the test is stable
        session = ServingSession(pol, backend, seed=9,
                                 retry=RetryPolicy(max_retries=30,
                                                   backoff_base=0.1 * MS))
        rng = np.random.default_rng(14)
        handles = [session.submit(wl.sample_request(rng, 0.0))
                   for _ in range(5)]
        session.drain()
        return engine, session, handles

    eng_f, sess_f, faulted = serve(FaultSpec(p_transient=0.1,
                                             fault_latency=0.2 * MS))
    assert sess_f.log.faults > 0, "spec/seed injected no faults — retune"
    assert sess_f.retried > 0
    eng_c, sess_c, clean = serve(None)
    assert all(h.state is HandleState.DONE for h in faulted)
    for hf, hc in zip(faulted, clean):
        assert hf.tokens, "finished request streamed no tokens"
        assert hf.tokens == hc.tokens            # bit-exact vs fault-free
    assert eng_f.slots_in_use == 0
    _pool_consistent(eng_f)


def test_jax_cancel_midflight_keeps_survivors_bit_exact():
    """Cancelling one batch member mid-decode frees its slot immediately
    and leaves the survivors' remaining tokens bit-exact."""
    from repro.serving.engine import JaxEngine
    from test_engine_memory import _pool_consistent, _workload

    cfg = _tiny()
    wl = _workload(cfg)
    perf = NPUPerfModel(PAPER_NPU)

    def serve(cancel_idx):
        engine = JaxEngine(cfg, max_len=32, n_slots=4)
        pol = LazyBatching(SlackPredictor.build([wl], perf, 60.0),
                           max_batch=4)
        session = ServingSession(pol, engine, seed=9)
        rng = np.random.default_rng(14)
        handles = [session.submit(wl.sample_request(rng, 0.0))
                   for _ in range(4)]
        session.step()                   # admit + first committed run
        if cancel_idx is not None:
            assert handles[cancel_idx].cancel()
        session.drain()
        return engine, handles

    eng, handles = serve(cancel_idx=1)
    _, ref = serve(cancel_idx=None)
    assert handles[1].state is HandleState.CANCELLED
    for i in (0, 2, 3):
        assert handles[i].state is HandleState.DONE
        assert handles[i].tokens == ref[i].tokens
    assert eng.slots_in_use == 0
    _pool_consistent(eng)
