"""Async-aware dataflow suite: yield-point CFG lowering, suspension
hooks, the three async checkers (await-atomicity, blocking-in-async,
task-leak) against racy fixtures and their clean twins, the loop-stall
sanitizer, and the real-tree-clean gate over the gateway package.

Fixture files live under ``tmp_path/repro/...`` because checker scoping
keys on the repo-relative suffix — same convention as
``test_dataflow.py``.
"""
import ast
import asyncio
from pathlib import Path

from repro.analysis.asyncrace import (AwaitAtomicityChecker,
                                      BlockingInAsyncChecker,
                                      TaskLeakChecker, owner_annotations)
from repro.analysis.base import SourceFile
from repro.analysis.cfg import build_cfg, contains_await, functions
from repro.analysis.dataflow import Analysis, analyze
from repro.analysis.lint import ALL_CHECKERS, run_lint
from repro.serving.gateway import LoopStallSanitizer

REPO = Path(__file__).resolve().parents[1]


def _func(src: str, name: str = None) -> ast.AST:
    tree = ast.parse(src)
    for f in functions(tree):
        if name is None or f.name == name:
            return f
    raise AssertionError(f"no function {name!r} in fixture")


def _write(tmp_path: Path, rel: str, text: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def _lint(tmp_path, rel, text, checker):
    p = _write(tmp_path, rel, text)
    return run_lint([p], checkers=[c for c in ALL_CHECKERS
                                   if c.name == checker])


def _lint_blocking(paths):
    return run_lint(paths, checkers=[],
                    project_checkers=[BlockingInAsyncChecker()])


# ---------------------------------------------------------------------------
# CFG yield-point lowering
# ---------------------------------------------------------------------------

def test_nested_def_awaits_do_not_yield_the_outer_function():
    # inner's awaits suspend the INNER coroutine, not outer
    cfg = build_cfg(_func(
        "async def outer():\n"
        "    async def inner():\n"
        "        await x()\n"
        "    return inner\n", name="outer"))
    assert _yield_nodes(cfg) == []
    assert contains_await(ast.parse(
        "async def f():\n    y = await x()\n").body[0].body[0])


def _yield_nodes(cfg):
    return [n for n in cfg.nodes.values() if n.kind == "yield"]


def test_await_statement_gets_a_yield_node_after_it():
    cfg = build_cfg(_func(
        "async def f(self):\n"
        "    v = self.x\n"
        "    await self.flush()\n"
        "    self.x = v\n"))
    ys = _yield_nodes(cfg)
    assert len(ys) == 1
    assert ys[0].stmt.lineno == 3
    # CancelledError is delivered at the suspension: live exc edge
    assert any(e.kind == "exc" for e in cfg.succs[ys[0].nid])


def test_async_for_yields_on_every_iteration():
    cfg = build_cfg(_func(
        "async def f(it):\n"
        "    async for x in it:\n"
        "        use(x)\n"))
    ys = _yield_nodes(cfg)
    assert len(ys) == 1
    # the loop back edge must pass through the yield node: every
    # __anext__ is an await
    assert any(e.dst == ys[0].nid for edges in cfg.succs.values()
               for e in edges if e.kind == "normal")


def test_async_with_yields_at_enter_and_exit():
    cfg = build_cfg(_func(
        "async def f(self):\n"
        "    async with self.lock:\n"
        "        work()\n"))
    assert len(_yield_nodes(cfg)) == 2


def test_sync_function_has_no_yield_nodes():
    cfg = build_cfg(_func("def f(x):\n    return x + 1\n"))
    assert _yield_nodes(cfg) == []


def test_engine_routes_yield_nodes_through_suspend():
    hits = []

    class Spy(Analysis):
        def suspend(self, state, node):
            hits.append(node.kind)
            return state

    cfg = build_cfg(_func(
        "async def f(self):\n    await self.flush()\n"))
    analyze(cfg, Spy())
    assert hits and set(hits) == {"yield"}


# ---------------------------------------------------------------------------
# await-atomicity: racy twin / clean twins
# ---------------------------------------------------------------------------

RACY_RMW = (
    "class App:\n"
    "    async def bump(self):\n"
    "        v = self.completed\n"
    "        await self.flush()\n"
    "        self.completed = v + 1\n")


def test_atomicity_flags_read_await_write(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py", RACY_RMW,
                "await-atomicity")
    assert len(res.new) == 1
    f = res.new[0]
    assert f.line == 5                       # reported AT the write
    assert "read at line 3" in f.message
    assert "await at line 4" in f.message


def test_atomicity_clean_when_reread_after_await(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    async def bump(self):\n"
                "        await self.flush()\n"
                "        self.completed = self.completed + 1\n",
                "await-atomicity")
    assert res.new == []


def test_atomicity_clean_under_asyncio_lock(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    async def bump(self):\n"
                "        async with self._lock:\n"
                "            v = self.completed\n"
                "            await self.flush()\n"
                "            self.completed = v + 1\n",
                "await-atomicity")
    assert res.new == []


def test_atomicity_clean_under_owner_annotation(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    def __init__(self):\n"
                "        self.completed = 0  # reprolint: owner=pump\n"
                + RACY_RMW.split("\n", 1)[1],
                "await-atomicity")
    assert res.new == []


def test_atomicity_flags_augassign_spanning_await_intra_stmt(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    async def bump(self):\n"
                "        self.total += await self.step()\n",
                "await-atomicity")
    assert len(res.new) == 1
    assert res.new[0].line == 3


def test_atomicity_flags_global_state_too(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "SEQ = 0\n"
                "async def bump():\n"
                "    global SEQ\n"
                "    v = SEQ\n"
                "    await flush()\n"
                "    SEQ = v + 1\n",
                "await-atomicity")
    assert len(res.new) == 1
    assert res.new[0].line == 6


def test_atomicity_ignores_sync_functions(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    def bump(self):\n"
                "        v = self.completed\n"
                "        self.completed = v + 1\n",
                "await-atomicity")
    assert res.new == []


def test_owner_annotation_parsing(tmp_path):
    p = _write(tmp_path, "repro/serving/gw.py",
               "class App:\n"
               "    def __init__(self):\n"
               "        self.active = {}  # reprolint: owner=pump\n"
               "        self.other = 0\n")
    owners = owner_annotations(SourceFile(p, p.read_text()))
    assert owners == {"active": "pump"}


# ---------------------------------------------------------------------------
# blocking-in-async: racy twin / clean twins
# ---------------------------------------------------------------------------

def test_blocking_direct_primitive_flagged(tmp_path):
    p = _write(tmp_path, "repro/serving/gw.py",
               "import time\n"
               "async def handler():\n"
               "    time.sleep(1)\n")
    res = _lint_blocking([p])
    assert len(res.new) == 1
    assert res.new[0].line == 3
    assert "time.sleep" in res.new[0].message


def test_blocking_one_hop_has_witness_chain(tmp_path):
    p = _write(tmp_path, "repro/serving/gw.py",
               "class App:\n"
               "    def helper(self):\n"
               "        self.session.run_until(5)\n"
               "    async def handler(self):\n"
               "        self.helper()\n")
    res = _lint_blocking([p])
    assert len(res.new) == 1
    f = res.new[0]
    assert f.line == 5                       # the async frontier call
    assert "helper" in f.message
    assert "session.run_until" in f.message  # the witness chain's seed


def test_blocking_awaited_coroutine_is_clean(tmp_path):
    # writer.drain() awaited = a coroutine, NOT the sync session.drain
    p = _write(tmp_path, "repro/serving/gw.py",
               "async def handler(writer):\n"
               "    await writer.drain()\n")
    res = _lint_blocking([p])
    assert res.new == []


def test_blocking_suppressed_seed_sanctions_callers(tmp_path):
    p = _write(tmp_path, "repro/serving/gw.py",
               "class Driver:\n"
               "    def advance(self):\n"
               "        self.session.run_until(5)"
               "  # reprolint: disable=blocking-in-async\n"
               "    async def pump(self):\n"
               "        self.advance()\n")
    res = _lint_blocking([p])
    assert res.new == []


def test_blocking_unawaited_async_callee_propagates_nothing(tmp_path):
    # calling an async def without await never runs its body, so its
    # blocking call cannot stall the caller (that drop is task-leak's)
    p = _write(tmp_path, "repro/serving/gw.py",
               "import time\n"
               "async def inner():\n"
               "    time.sleep(1)\n"
               "def outer():\n"
               "    inner()\n")
    res = _lint_blocking([p])
    # the only finding is inner's own direct primitive
    assert [f.line for f in res.new] == [3]


def test_blocking_not_reported_in_test_files(tmp_path):
    p = _write(tmp_path, "tests/test_gw.py",
               "import time\n"
               "async def handler():\n"
               "    time.sleep(1)\n")
    res = _lint_blocking([p])
    assert res.new == []


# ---------------------------------------------------------------------------
# task-leak: racy twin / clean twins
# ---------------------------------------------------------------------------

def test_task_leak_dropped_and_unused_handles(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "import asyncio\n"
                "class App:\n"
                "    async def fire(self):\n"
                "        asyncio.create_task(self.pump())\n"
                "    async def bind(self):\n"
                "        t = asyncio.create_task(self.pump())\n"
                "    async def pump(self):\n"
                "        pass\n",
                "task-leak")
    assert sorted(f.line for f in res.new) == [4, 6]


def test_task_leak_tracked_handles_are_clean(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "import asyncio\n"
                "class App:\n"
                "    async def keep(self):\n"
                "        self._t = asyncio.create_task(self.pump())\n"
                "    async def use(self):\n"
                "        t = asyncio.create_task(self.pump())\n"
                "        await t\n"
                "    async def pump(self):\n"
                "        pass\n",
                "task-leak")
    assert res.new == []


def test_task_leak_never_awaited_coroutine(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    async def go(self):\n"
                "        self.pump()\n"
                "    async def pump(self):\n"
                "        pass\n",
                "task-leak")
    assert len(res.new) == 1
    assert res.new[0].line == 3


def test_task_leak_other_objects_sync_method_is_clean(tmp_path):
    # self.driver.start() is ANOTHER object's sync start, not this
    # class's async start — the leaf-name match must not fire
    res = _lint(tmp_path, "repro/serving/gw.py",
                "class App:\n"
                "    async def start(self):\n"
                "        self.driver.start()\n"
                "        await self.pump()\n"
                "    async def pump(self):\n"
                "        pass\n",
                "task-leak")
    assert res.new == []


def test_task_leak_swallowed_cancellation(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "import asyncio\n"
                "async def handler(q):\n"
                "    try:\n"
                "        await q.get()\n"
                "    except asyncio.CancelledError:\n"
                "        pass\n",
                "task-leak")
    assert len(res.new) == 1
    assert "swallows the cancellation" in res.new[0].message


def test_task_leak_reraise_and_reap_idiom_are_clean(tmp_path):
    res = _lint(tmp_path, "repro/serving/gw.py",
                "import asyncio\n"
                "async def handler(q):\n"
                "    try:\n"
                "        await q.get()\n"
                "    except asyncio.CancelledError:\n"
                "        cleanup()\n"
                "        raise\n"
                "async def reap(task):\n"
                "    task.cancel()\n"
                "    try:\n"
                "        await task\n"
                "    except asyncio.CancelledError:\n"
                "        pass\n",
                "task-leak")
    assert res.new == []


# ---------------------------------------------------------------------------
# loop-stall sanitizer
# ---------------------------------------------------------------------------

def test_sanitizer_counts_a_seeded_stall():
    async def scenario():
        san = LoopStallSanitizer(interval=0.001, threshold=0.02)
        san.start()
        await asyncio.sleep(0.01)            # let probes establish
        import time
        time.sleep(0.05)  # deliberate stall  # reprolint: disable=blocking-in-async
        await asyncio.sleep(0.01)
        await san.stop()
        return san.stats

    stats = asyncio.run(scenario())
    assert stats.ticks > 0
    assert stats.stalls >= 1
    assert stats.max_lag_s >= 0.02
    assert stats.lag_p99_s() >= 0.0
    d = stats.as_dict()
    assert d["stalls"] == stats.stalls


def test_sanitizer_quiet_loop_counts_no_stalls():
    async def scenario():
        san = LoopStallSanitizer(interval=0.001, threshold=0.25)
        san.start()
        await asyncio.sleep(0.02)
        await san.stop()
        return san.stats

    stats = asyncio.run(scenario())
    assert stats.ticks > 0
    assert stats.stalls == 0


def test_sanitizer_stop_reaps_its_task():
    async def scenario():
        san = LoopStallSanitizer()
        san.start()
        task = san._task
        await san.stop()
        return task

    task = asyncio.run(scenario())
    assert task.done()


# ---------------------------------------------------------------------------
# the real tree is clean
# ---------------------------------------------------------------------------

def test_gateway_tree_is_clean_under_async_checkers():
    gw = REPO / "src" / "repro" / "serving" / "gateway"
    res = run_lint(
        [gw, REPO / "src" / "repro" / "launch"],
        checkers=[AwaitAtomicityChecker(), TaskLeakChecker()],
        project_checkers=[BlockingInAsyncChecker()])
    assert res.new == [], [str(f) for f in res.new]
