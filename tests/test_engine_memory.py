"""Paged KV arena: reclamation, memory caps, accounting, slot-pool safety.

The reclaimable arena must be a pure memory change: growth, shrink, and
slot relocation may never alter generated tokens (bit-exact vs a
grow-only arena), and the free-slot pool must stay consistent (no slot
leaked, none double-issued) under ANY interleaving of
prepare/release/grow/shrink — property-tested with hypothesis.

ACCEPTANCE: after a burst of N requests drains, arena capacity (and
``memory_stats().bytes_resident``) returns to within 2x of steady-state
occupancy, with decode outputs bit-exact vs the grow-only arena.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import SubBatch
from repro.serving.backend import MultiBackend
from repro.serving.engine import _PAD_SLOT, JaxEngine
from repro.serving.workload import LengthDist, from_model_config


def _tiny(arch="llama3.2-1b"):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                               num_prefix_embeddings=0)


def _workload(cfg):
    return from_model_config(cfg,
                             prompt_dist=LengthDist((5, 7), (0.5, 0.5)),
                             decode_dist=LengthDist((2, 3), (0.5, 0.5)))


def _mk_req(wl, rng, prompt_len, decode_len):
    r = wl.sample_request(rng, 0.0)
    seq, prefix_len, cycle_len = wl.build_sequence(prompt_len, decode_len)
    r.sequence, r.prefix_len, r.cycle_len = seq, prefix_len, cycle_len
    r.prompt_len, r.decode_len = prompt_len, decode_len
    return r


def _run_fused(engine, req, *, stop_before=None, stop_after=None):
    """Drive ``req`` alone by committed fused runs until a stop or done."""
    sb = SubBatch([req])
    run = sb.run_nodes(stop_before=stop_before or (),
                       stop_after=stop_after or ())
    engine.execute_run("m", sb, run)
    sb.advance_n(len(run), 0.0)


def _finish(engine, req):
    sb = SubBatch([req])
    while sb.size:
        run = sb.run_nodes(stop_after={"head"})
        engine.execute_run("m", sb, run)
        sb.advance_n(len(run), 0.0)


def _prefill(engine, req):
    _run_fused(engine, req, stop_before={"D0"})


def _pool_consistent(engine):
    free = list(engine._free_slots)
    used = list(engine._slot.values())
    assert len(set(free)) == len(free), f"free pool has duplicates: {free}"
    assert len(set(used)) == len(used), f"slot double-issued: {used}"
    assert not set(free) & set(used), "slot simultaneously free and used"
    assert sorted(free + used) == list(range(engine.n_slots)), \
        f"slot leak: {sorted(free + used)} != 0..{engine.n_slots - 1}"


# ---------------------------------------------------------------------------
# ACCEPTANCE: burst -> drain reclaims capacity, bit-exact vs grow-only
# ---------------------------------------------------------------------------

def test_burst_drain_returns_capacity_within_2x_of_occupancy():
    cfg = _tiny()
    wl = _workload(cfg)
    rng = np.random.default_rng(0)
    engine = JaxEngine(cfg, max_len=32, n_slots=2, max_slots=64,
                       min_slots=2)
    N = 10
    burst, prompts = [], []
    for _ in range(N):
        r = _mk_req(wl, rng, 5, 3)
        p = rng.integers(2, cfg.vocab_size, size=5)
        engine.register(r, p)
        _prefill(engine, r)          # every member occupies a slot
        burst.append(r)
        prompts.append(p)
    grown = engine.n_slots
    bytes_peak = engine.memory_stats().bytes_resident
    assert grown >= N and engine.n_grows > 0

    # drain the burst down to a steady state of 2 live requests (both must
    # survive slot relocation during compaction)
    steady = burst[-2:]
    for r in burst[:-2]:
        _finish(engine, r)
    live = engine.slots_in_use
    assert live == 2
    stats = engine.memory_stats()
    assert engine.n_shrinks > 0
    assert engine.n_slots <= 2 * live, \
        f"capacity {engine.n_slots} not within 2x of occupancy {live}"
    assert stats.bytes_resident <= bytes_peak * (engine.n_slots / grown) + 1
    assert stats.slots_total == engine.n_slots

    # the survivors decode to completion ON the shrunken arena
    for r in steady:
        _finish(engine, r)

    # bit-exactness: identical prompts through a grow-only arena
    ref = JaxEngine(cfg, max_len=32, n_slots=2, max_slots=64, min_slots=2,
                    auto_shrink=False)
    rng2 = np.random.default_rng(99)
    for r, p in zip(burst, prompts):
        q = _mk_req(wl, rng2, 5, 3)
        ref.register(q, p)
        _finish(ref, q)
        assert engine.states[r.rid].generated == ref.states[q.rid].generated
    assert ref.n_shrinks == 0 and engine.n_shrinks > 0
    _pool_consistent(engine)


# ---------------------------------------------------------------------------
# Satellite: growth guards
# ---------------------------------------------------------------------------

def test_grow_is_guarded_against_pad_slot_sentinel():
    """Growth must never bring a real slot index into the padded-row
    sentinel's range — a padding row's dropped scatter would silently
    alias a live slot."""
    cfg = _tiny()
    engine = JaxEngine(cfg, max_len=32)
    engine.n_slots = int(_PAD_SLOT) // 2 + 1     # next double would alias
    with pytest.raises(RuntimeError, match="sentinel"):
        engine._grow_arena()


def test_max_slots_cap_raises_when_exhausted():
    cfg = _tiny()
    wl = _workload(cfg)
    rng = np.random.default_rng(1)
    engine = JaxEngine(cfg, max_len=32, n_slots=2, max_slots=4)
    reqs = []
    for _ in range(4):
        r = _mk_req(wl, rng, 5, 2)
        engine.register(r, rng.integers(2, cfg.vocab_size, size=5))
        _prefill(engine, r)
        reqs.append(r)
    assert engine.n_slots == 4 and engine.slots_in_use == 4
    extra = _mk_req(wl, rng, 5, 2)
    engine.register(extra, rng.integers(2, cfg.vocab_size, size=5))
    with pytest.raises(RuntimeError, match="memory cap"):
        _prefill(engine, extra)
    # the cap is a real bound, not a crash state: finishing one request
    # frees its slot and the parked one proceeds
    _finish(engine, reqs[0])
    _finish(engine, extra)
    assert extra.done and engine.states[extra.rid].generated


# ---------------------------------------------------------------------------
# memory_stats across the Backend contract
# ---------------------------------------------------------------------------

def test_engine_memory_stats_track_arena():
    cfg = _tiny()
    wl = _workload(cfg)
    rng = np.random.default_rng(2)
    engine = JaxEngine(cfg, max_len=32, n_slots=4)
    s0 = engine.memory_stats()
    assert s0.slots_total == 4 and s0.slots_live == 0 and s0.slots_free == 4
    assert s0.bytes_resident > 0
    assert s0.bytes_per_slot == pytest.approx(s0.bytes_resident / 4)
    assert s0.max_slots is None and s0.pool == id(engine)

    r = _mk_req(wl, rng, 5, 2)
    engine.register(r, rng.integers(2, cfg.vocab_size, size=5))
    _prefill(engine, r)
    s1 = engine.memory_stats()
    assert s1.slots_live == 1 and s1.slots_free == 3
    assert s1.bytes_resident == s0.bytes_resident     # pinned: no growth
    _finish(engine, r)
    assert engine.memory_stats().slots_live == 0


def test_multibackend_memory_stats_route_and_aggregate():
    cfg = _tiny()
    eng_a = JaxEngine(cfg, max_len=32, n_slots=2, max_slots=8)
    eng_b = JaxEngine(cfg, max_len=32, n_slots=4, max_slots=8)
    mux = MultiBackend({"a": eng_a, "b": eng_b})
    assert mux.memory_stats("a").pool == id(eng_a)
    assert mux.memory_stats("b").pool == id(eng_b)
    assert mux.memory_stats("a").slots_total == 2
    agg = mux.memory_stats()
    assert agg.slots_total == 6 and agg.max_slots == 16
    assert agg.bytes_resident == (eng_a.memory_stats().bytes_resident
                                  + eng_b.memory_stats().bytes_resident)
    # a shared inner backend is counted once in the aggregate
    mux2 = MultiBackend({"x": eng_a, "y": eng_a})
    assert mux2.memory_stats().slots_total == 2


# ---------------------------------------------------------------------------
# Bounded-memory JAX serving end to end: the new scenario family
# ---------------------------------------------------------------------------

def test_jax_session_burst_respects_slot_cap():
    """A burst bigger than ``max_slots`` through a full ServingSession:
    memory-aware admission defers the overflow, so the paged arena never
    exhausts and everything completes; memory-blind scheduling of the
    same burst overruns the cap and crashes the engine."""
    from repro.core.policies import LazyBatching
    from repro.core.slack import SlackPredictor
    from repro.serving.npu_model import NPUPerfModel, TPU_V5E
    from repro.serving.session import ServingSession

    cfg = _tiny()
    wl = _workload(cfg)
    perf = NPUPerfModel(TPU_V5E)

    def serve(memory_aware):
        engine = JaxEngine(cfg, max_len=32, n_slots=2, max_slots=4,
                           min_slots=2)
        pol = LazyBatching(SlackPredictor.build([wl], perf, 60.0),
                           max_batch=8)
        session = ServingSession(pol, engine, memory_aware=memory_aware)
        rng = np.random.default_rng(4)
        for i in range(8):                       # burst of 8 > 4 slots
            r = wl.sample_request(rng, 0.0)
            session.submit(
                r, prompt_tokens=rng.integers(2, cfg.vocab_size,
                                              size=r.prompt_len))
        stats = session.drain()
        return engine, stats

    engine, stats = serve(memory_aware=True)
    assert len(stats.finished) == 8
    assert engine.slots_in_use == 0
    assert engine.n_slots <= 4
    _pool_consistent(engine)

    with pytest.raises(RuntimeError, match="memory cap"):
        serve(memory_aware=False)


# The prepare/release/grow/shrink interleaving property test lives in
# ``test_engine_memory_property.py`` (module-level hypothesis importorskip
# must not take these deterministic tests down with it).
