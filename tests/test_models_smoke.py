"""Per-architecture smoke tests on REDUCED variants (brief requirement):

<=2 layers (hybrid: one pattern group), d_model<=512, <=4 experts; one
forward/train step + one prefill + one ragged decode step on CPU, asserting
output shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import Model, RuntimeFlags

ARCHS = sorted(ARCHITECTURES)
FLAGS = RuntimeFlags(dtype=jnp.float32, attn_chunk=64)


def _batch_for(cfg, key, batch=2, seq=32):
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.modality is not None:
        out["prefix"] = jax.random.normal(
            key, (batch, cfg.num_prefix_embeddings, cfg.d_model)) * 0.02
    return out


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_reduced(arch):
    red = get_config(arch).reduced()
    assert red.d_model <= 512
    assert red.num_layers <= max(2, len(red.hybrid.block_pattern) if red.hybrid else 2)
    if red.moe:
        assert red.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_forward(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, FLAGS)
    params = model.init(rng)
    batch = _batch_for(cfg, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_ragged_decode(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, FLAGS)
    params = model.init(rng)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.modality is not None:
        prefix = jax.random.normal(rng, (B, cfg.num_prefix_embeddings,
                                         cfg.d_model)) * 0.02
    max_len = 64
    logits, _prefill_cache = jax.jit(
        lambda p, t: model.prefill(p, t, prefix=prefix))(params, tokens)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # ragged decode: rows at different positions (lazily merged batch)
    cache = model.init_cache(B, max_len)
    pos = jnp.array([0, 5], jnp.int32)
    tok = jnp.array([1, 2], jnp.int32)
    dec = jax.jit(model.decode_step)
    logits2, cache2 = dec(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    logits3, _ = dec(params, cache2, tok, pos + 1)
    assert np.all(np.isfinite(np.asarray(logits3, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_analytic(arch, rng):
    """Analytic param_count() tracks the real pytree within 12%."""
    cfg = get_config(arch).reduced()
    model = Model(cfg, FLAGS)
    params = jax.eval_shape(model.init, rng)
    real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(real - analytic) / real < 0.12, (arch, real, analytic)


def test_scan_matches_unrolled(rng):
    """use_scan=True and False must be numerically identical."""
    cfg = get_config("llama3.2-1b").reduced()
    batch = _batch_for(cfg, rng)
    m1 = Model(cfg, RuntimeFlags(dtype=jnp.float32, use_scan=True, attn_chunk=64))
    m2 = Model(cfg, RuntimeFlags(dtype=jnp.float32, use_scan=False, attn_chunk=64))
    params = m1.init(rng)
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_sliding_window_variant_decodes(rng):
    """Dense arch long-context variant: ring-buffer window cache."""
    cfg = get_config("mistral-nemo-12b").reduced()
    flags = RuntimeFlags(dtype=jnp.float32, window=8, attn_chunk=64)
    model = Model(cfg, flags)
    params = model.init(rng)
    B = 2
    cache = model.init_cache(B, max_len=1024)
    # cache length must be the window, not max_len (axis 0 = layers, 1 = batch)
    kv = jax.tree.leaves(cache)[0]
    assert kv.shape[2] == 8
    tok = jnp.zeros((B,), jnp.int32)
    dec = jax.jit(model.decode_step)
    for step in range(12):   # wrap the ring buffer
        pos = jnp.full((B,), step, jnp.int32)
        logits, cache = dec(params, cache, tok, pos)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
