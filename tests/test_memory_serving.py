"""Memory-pressure-aware serving: accounting, admission, metrics, ordering.

Covers the bounded-memory serving stack end to end:
  * SimExecutor slot accounting: live slots tracked per model, analytic
    KV bytes, and the oversubscription thrash penalty past ``max_slots``,
  * memory-aware admission (session-wired gate): live residency never
    exceeds the pool cap, everything still completes (overflow defers in
    the InfQ instead of oversubscribing),
  * ACCEPTANCE: two-tenant overload with a slot cap — memory-aware lazyb
    with per-model memory shares holds the interactive class's attainment
    strictly above the memory-blind baseline,
  * rejected requests count as SLA violations (attainment / violation
    rate / per-class / per-model), NaN-safe when a class is all-rejected,
  * memory-infeasible rejection: a request that cannot get a KV slot
    before its deadline is REJECTED at submit when admission control is on,
  * deterministic cross-model ordering for same-timestamp arrivals
    (tiebreak on rid, independent of submission/registration order),
  * the JAX engine's paged arena under a session slot cap: memory-aware
    admission keeps a burst inside ``max_slots`` (no arena-exhausted
    crash) where memory-blind scheduling overruns the cap.
"""
import numpy as np
import pytest

from repro.core import (LazyBatching, LeastSlackArbiter, RoundRobinArbiter,
                        SLAClass, SlackPredictor)
from repro.serving import (NPUPerfModel, PAPER_NPU, ServingSession,
                           SimExecutor, get_workload, poisson_mixture,
                           poisson_trace)
from repro.serving.metrics import ServeStats
from repro.serving.session import HandleState, run_mixture, run_trace

PERF = NPUPerfModel(PAPER_NPU)
WL = {n: get_workload(n) for n in ("transformer", "gnmt")}
GOLD = SLAClass("gold", 0.04)
BULK = SLAClass("bulk", 0.4)


def lazyb(wl, sla=0.1, max_batch=16):
    return LazyBatching(SlackPredictor.build([wl], PERF, sla),
                        max_batch=max_batch)


# ---------------------------------------------------------------------------
# SimExecutor: slot accounting + oversubscription thrash
# ---------------------------------------------------------------------------

def test_sim_executor_slot_accounting_and_release():
    wl = WL["transformer"]
    rng = np.random.default_rng(0)
    ex = SimExecutor(PERF, max_slots=4)
    reqs = [wl.sample_request(rng, 0.0) for _ in range(3)]
    from repro.core.request import SubBatch
    sb = SubBatch(list(reqs))
    ex.execute("m", sb, sb.node_id)
    st = ex.memory_stats("m")
    assert st.slots_live == 3 and st.slots_free == 1
    assert st.max_slots == 4 and st.bytes_resident > 0
    ex.on_finished("m", reqs[:2])
    st = ex.memory_stats("m")
    assert st.slots_live == 1 and st.slots_free == 3
    # pool identity: every model name shares the one simulated device
    assert ex.memory_stats("other").pool == st.pool == id(ex)


def test_sim_executor_thrash_penalty_past_cap():
    """Past the cap every dispatch pays live/max_slots — the cost a
    memory-blind policy eats; at/below the cap latency is untouched
    (and max_slots=None stays bit-identical to the seed)."""
    wl = WL["transformer"]
    rng = np.random.default_rng(1)
    from repro.core.request import SubBatch
    reqs = [wl.sample_request(rng, 0.0) for _ in range(4)]

    free = SimExecutor(PERF)
    capped = SimExecutor(PERF, max_slots=4)
    tight = SimExecutor(PERF, max_slots=2)
    sb = SubBatch(list(reqs))
    lat_free = free.execute("m", sb, sb.node_id)
    lat_capped = capped.execute("m", SubBatch(list(reqs)), sb.node_id)
    lat_tight = tight.execute("m", SubBatch(list(reqs)), sb.node_id)
    assert lat_capped == lat_free                 # 4 live <= 4 slots
    assert lat_tight == pytest.approx(lat_free * 2.0)   # 4 live / 2 slots


# ---------------------------------------------------------------------------
# Memory-aware admission: residency bounded, work defers instead
# ---------------------------------------------------------------------------

def test_memory_gate_bounds_live_residency():
    """Single model, pool of 6 slots, heavy burst: the session-wired gate
    must keep backend residency (and the policy's admitted set) at or
    under the cap at EVERY scheduling step, while every request still
    completes (deferred, not dropped)."""
    wl = WL["transformer"]
    M = 6
    backend = SimExecutor(PERF, max_slots=M)
    session = ServingSession(lazyb(wl, max_batch=16), backend)
    trace = poisson_trace(wl, 800, 0.05, seed=2)
    session.duration = trace.duration
    for r in sorted(trace.requests, key=lambda r: r.arrival):
        session.submit(r)
    peak = 0
    while session.step():
        peak = max(peak, backend.memory_stats().slots_live,
                   session.policy.admitted)
    stats = session.stats()
    assert peak <= M, f"residency peaked at {peak} > cap {M}"
    assert len(stats.finished) == len(trace.requests)
    assert stats.rejected == 0


def test_memory_blind_session_overruns_the_cap():
    """Sanity for the A/B: with memory_aware=False the same overload
    oversubscribes the pool (that is what the thrash penalty prices)."""
    wl = WL["transformer"]
    backend = SimExecutor(PERF, max_slots=6)
    session = ServingSession(lazyb(wl, max_batch=16), backend,
                             memory_aware=False)
    trace = poisson_trace(wl, 800, 0.05, seed=2)
    for r in sorted(trace.requests, key=lambda r: r.arrival):
        session.submit(r)
    peak = 0
    while session.step():
        peak = max(peak, backend.memory_stats().slots_live)
    assert peak > 6


# ---------------------------------------------------------------------------
# ACCEPTANCE: two-tenant overload under a slot cap
# ---------------------------------------------------------------------------

def _gold_bulk(memory_aware, shares, M=8, seed=0):
    mix = poisson_mixture([("tf", WL["transformer"], 500),
                           ("gn", WL["gnmt"], 500)], 0.25, seed=seed)
    for r in mix.requests:
        r.sla = GOLD if r.model == "tf" else BULK
    models = [("tf", WL["transformer"], lazyb(WL["transformer"], 0.04)),
              ("gn", WL["gnmt"], lazyb(WL["gnmt"], 0.4))]
    return run_mixture(models, SimExecutor(PERF, max_slots=M), mix.fresh(),
                       arbiter=LeastSlackArbiter(mem_shares=shares),
                       memory_aware=memory_aware)


@pytest.mark.parametrize("seed", [0, 1])
def test_memory_shares_protect_interactive_tenant(seed):
    """Two tenants, one bounded KV pool: memory-aware lazyb admission with
    per-model memory shares holds the interactive (gold) class's
    attainment STRICTLY above the memory-blind baseline, which lets the
    bulk tenant flood the pool and thrash every dispatch."""
    blind = _gold_bulk(False, None, seed=seed)
    aware = _gold_bulk(True, {"tf": 0.5, "gn": 0.5}, seed=seed)
    g_blind = blind.per_model()["tf"]["sla_attainment"]
    g_aware = aware.per_model()["tf"]["sla_attainment"]
    assert g_aware > g_blind, (g_aware, g_blind)
    assert g_aware > 0.95
    # the bulk tenant is capped, not starved (it may trade some of its own
    # attainment for the interactive guarantee — that is the contract)
    assert aware.per_model()["gn"]["completed"] > 0


def test_share_is_a_reservation_against_uncapped_tenants():
    """A model's share reserves its slots even against tenants with NO
    share of their own: an uncapped bulk flood can only draw from the
    unreserved remainder of the pool, and the shared model's reserve is
    intact when its traffic shows up."""
    M = 8
    backend = SimExecutor(PERF, max_slots=M)
    session = ServingSession(backend=backend)
    session.register("tf", WL["transformer"],
                     policy=lazyb(WL["transformer"], 0.04), mem_share=0.5)
    session.register("gn", WL["gnmt"], policy=lazyb(WL["gnmt"], 0.4))
    rng = np.random.default_rng(8)
    for _ in range(16):                  # bulk-only flood, gold still idle
        session.submit(WL["gnmt"].sample_request(rng, 0.0), model="gn")
    for _ in range(6):
        session.step()
        assert session.registry["gn"].policy.admitted <= M - 4, \
            "uncapped tenant dipped into the shared tenant's reservation"
    # the reserve is available the moment the shared tenant needs it
    h = session.submit(WL["transformer"].sample_request(rng, session.now),
                       model="tf")
    session.step()
    assert session.registry["tf"].policy.admitted >= 1
    session.drain()
    assert h.state is HandleState.DONE


def test_unshared_pool_lets_bulk_starve_interactive():
    """Motivation check for shares: memory-aware admission WITHOUT shares
    lets the bulk tenant grab the whole pool first — the interactive
    tenant defers behind it and its attainment collapses below even the
    blind baseline. Shares are what make the pool starvation-proof."""
    noshare = _gold_bulk(True, None, seed=0)
    shared = _gold_bulk(True, {"tf": 0.5, "gn": 0.5}, seed=0)
    assert (shared.per_model()["tf"]["sla_attainment"]
            > noshare.per_model()["tf"]["sla_attainment"] + 0.3)


# ---------------------------------------------------------------------------
# Rejections are SLA violations (paper counts all SUBMITTED requests)
# ---------------------------------------------------------------------------

def _mk_finished(wl, rng, latency, sla=None):
    r = wl.sample_request(rng, 0.0)
    r.sla = sla
    r.t_finish = latency
    r.idx = len(r.sequence)
    return r


def test_rejections_count_as_sla_violations():
    wl = WL["transformer"]
    rng = np.random.default_rng(3)
    ok = _mk_finished(wl, rng, 0.01, GOLD)
    late = _mk_finished(wl, rng, 9.0, GOLD)
    rej = wl.sample_request(rng, 0.0)
    rej.sla = GOLD
    stats = ServeStats(policy="p", duration=1.0, finished=[ok, late],
                       rejected=1, rejected_requests=[rej],
                       classes={"gold": GOLD.deadline})
    # 3 submitted, 1 met: attainment 1/3, violation 2/3
    assert stats.attainment() == pytest.approx(1 / 3)
    assert stats.sla_violation_rate(GOLD.deadline, "gold") == \
        pytest.approx(2 / 3)
    pc = stats.per_class()
    assert pc["gold"]["completed"] == 2 and pc["gold"]["rejected"] == 1
    assert pc["gold"]["sla_attainment"] == pytest.approx(1 / 3)
    pm = stats.per_model()
    assert pm[wl.name]["rejected"] == 1
    assert pm[wl.name]["sla_attainment"] == pytest.approx(1 / 3)


def test_all_rejected_class_is_nan_safe():
    """A class with no finishers and only rejections: violation rate is
    1.0 (not NaN — every submission missed), latency percentiles stay
    NaN, and nothing raises."""
    wl = WL["transformer"]
    rng = np.random.default_rng(4)
    rej = wl.sample_request(rng, 0.0)
    rej.sla = GOLD
    ok = _mk_finished(wl, rng, 0.01, BULK)
    stats = ServeStats(policy="p", duration=1.0, finished=[ok],
                       rejected=1, rejected_requests=[rej],
                       classes={"gold": GOLD.deadline,
                                "bulk": BULK.deadline})
    pc = stats.per_class()
    assert pc["gold"]["completed"] == 0 and pc["gold"]["rejected"] == 1
    assert pc["gold"]["sla_violation_rate"] == 1.0
    assert pc["gold"]["sla_attainment"] == 0.0
    assert np.isnan(pc["gold"]["p50_ms"]) and np.isnan(pc["gold"]["ttft_ms"])
    assert pc["bulk"]["sla_attainment"] == 1.0
    # aggregate attainment blends both classes per-request: 1 of 2 met
    assert stats.attainment() == pytest.approx(0.5)
    # an empty-but-registered class still reports NaN (no submissions)
    stats2 = ServeStats(policy="p", duration=1.0, classes={"ghost": 0.1})
    assert np.isnan(stats2.per_class()["ghost"]["sla_violation_rate"])


def test_policy_cannot_inflate_attainment_by_rejecting():
    """End-to-end: with admission control on, an overloaded tier's
    rejections drag attainment down exactly like violations would — the
    'reject everything hard' strategy can no longer report a clean SLA."""
    wl = WL["transformer"]
    pol = lazyb(wl, sla=1e-6)       # nothing can meet this target
    stats = run_trace(pol, SimExecutor(PERF),
                      poisson_trace(wl, 100, 0.05, seed=5),
                      reject_infeasible=True)
    assert stats.rejected == len(stats.rejected_requests) > 0
    # every submission is judged: rejections are violations
    assert stats.attainment(1e-6) == 0.0
    assert stats.sla_violation_rate(1e-6) == 1.0


# ---------------------------------------------------------------------------
# Memory-infeasible rejection
# ---------------------------------------------------------------------------

def test_pool_exhaustion_past_deadline_rejects_at_submit():
    """One slot, held by a long request: a submission whose deadline is
    meetable ALONE (so the plain single-input bound passes) but not after
    waiting for the slot to free is REJECTED at submit — the
    memory-infeasible path specifically; a loose-deadline one is accepted
    and defers."""
    wl = WL["transformer"]
    backend = SimExecutor(PERF, max_slots=1)
    session = ServingSession(lazyb(wl, sla=10.0), backend,
                             reject_infeasible=True)
    rng = np.random.default_rng(6)
    first = session.submit(wl.sample_request(rng, 0.0))
    session.step()                   # admit + start: slot now held
    assert session.policy.admitted == 1

    pred = session.policy.predictor
    doomed = wl.sample_request(rng, 0.0)
    need = pred.single_total(doomed)
    wait = pred.release_bound(session.policy.admitted_requests)
    assert wait > 0
    # feasible alone (deadline > need) but not behind the held slot
    # (deadline < wait + need): only the memory path can reject this
    doomed.sla = SLAClass("tight", need + 0.5 * wait)
    h_doomed = session.submit(doomed)
    assert h_doomed.state is HandleState.REJECTED

    patient = wl.sample_request(rng, 0.0)
    patient.sla = SLAClass("loose", 10.0)
    h_patient = session.submit(patient)
    assert h_patient.state is HandleState.QUEUED
    session.drain()
    assert h_patient.state is HandleState.DONE
    assert first.state is HandleState.DONE
    assert session.stats().rejected == 1


# ---------------------------------------------------------------------------
# Deterministic same-timestamp cross-model arrival order
# ---------------------------------------------------------------------------

class _RecordingPolicy(LazyBatching):
    """LazyBatching that records the global enqueue order."""

    def __init__(self, pred, book):
        super().__init__(pred, max_batch=16)
        self._book = book

    def enqueue(self, req, now):
        self._book.append(req.rid)
        super().enqueue(req, now)


def test_same_timestamp_arrivals_order_is_submission_independent():
    """Two models submit at IDENTICAL timestamps: the arrivals heap must
    break ties on an intrinsic key (rid), so the enqueue order into the
    policies is the same no matter which model's requests were submitted
    (or registered) first — never dict/registration iteration order."""
    t_same = 0.005
    rng = np.random.default_rng(7)
    reqs_a = [WL["transformer"].sample_request(rng, t_same) for _ in range(2)]
    reqs_b = [WL["gnmt"].sample_request(rng, t_same) for _ in range(2)]
    for r in reqs_a:
        r.model = "tf"
    for r in reqs_b:
        r.model = "gn"

    def serve(submit_order, register_order):
        book = []
        session = ServingSession(backend=SimExecutor(PERF))
        entries = {"tf": WL["transformer"], "gn": WL["gnmt"]}
        for name in register_order:
            wl = entries[name]
            session.register(name, wl, policy=_RecordingPolicy(
                SlackPredictor.build([wl], PERF, 0.1), book))
        for r in submit_order:
            session.submit(r.clone())
        session.drain()
        return book

    b1 = serve(reqs_a + reqs_b, ["tf", "gn"])
    # resubmit the other way around, with registration order flipped too
    b2 = serve(reqs_b + reqs_a, ["gn", "tf"])
    assert b1 == b2, f"enqueue order depends on submission order: {b1} != {b2}"
    assert b1 == sorted(b1), "same-timestamp ties must break on rid"
