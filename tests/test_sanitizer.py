"""Runtime hot-path sanitizer: the dynamic half of reprolint.

The static checkers prove the engine *source* contains no stray sync or
retrace constructs; these tests prove the *execution* honors the PR 2
contract — after warmup, N fused decode cycles cost at most one host
sync per committed run and ZERO retraces (the jit cache is keyed only
by pow2-bucketed statics, so steady-state shapes never recompile).
Retraces are counted exactly: a Python-side counter increment inside
each jitted body runs only while JAX traces.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import SubBatch
from repro.serving.backend import Backend, MultiBackend, SanitizerStats
from repro.serving.engine import JaxEngine
from repro.serving.workload import LengthDist, from_model_config


def _tiny():
    cfg = get_config("llama3.2-1b").reduced()
    return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                               num_prefix_embeddings=0)


def _workload(cfg):
    return from_model_config(cfg,
                             prompt_dist=LengthDist((6,), (1.0,)),
                             decode_dist=LengthDist((3,), (1.0,)))


def _mk_req(wl, rng, prompt_len=6, decode_len=3):
    r = wl.sample_request(rng, 0.0)
    seq, prefix_len, cycle_len = wl.build_sequence(prompt_len, decode_len)
    r.sequence, r.prefix_len, r.cycle_len = seq, prefix_len, cycle_len
    r.prompt_len, r.decode_len = prompt_len, decode_len
    return r


def _finish(engine, req):
    sb = SubBatch([req])
    while sb.size:
        run = sb.run_nodes(stop_after={"head"})
        engine.execute_run("m", sb, run)
        sb.advance_n(len(run), 0.0)


def test_steady_state_fused_decode_is_one_sync_zero_retrace():
    """The headline contract: warm the jit cache with one request, then
    serve an identically-shaped one — every committed run costs <= 1
    host sync and the steady-state window adds ZERO retraces."""
    cfg = _tiny()
    engine = JaxEngine(cfg, max_len=64)
    wl = _workload(cfg)
    rng = np.random.default_rng(0)

    warm = _mk_req(wl, rng)
    engine.prepare("m", warm, rng)
    _finish(engine, warm)
    s0 = engine.sanitizer_stats()
    assert s0.retraces > 0               # warmup compiles show up
    assert s0.runs > 0

    req = _mk_req(wl, rng)
    engine.prepare("m", req, rng)
    _finish(engine, req)
    s1 = engine.sanitizer_stats()

    d_runs = s1.runs - s0.runs
    assert d_runs > 0
    assert s1.retraces - s0.retraces == 0, \
        "steady-state decode recompiled — a jit-cache key leaked a " \
        "dynamic scalar"
    assert s1.host_syncs - s0.host_syncs <= d_runs, \
        "more host syncs than committed runs — a hidden sync crept " \
        "into the hot path"
    assert s1.max_syncs_per_run <= 1
    assert s1.ok


def test_sanitizer_counts_runs_and_syncs_monotonically():
    cfg = _tiny()
    engine = JaxEngine(cfg, max_len=64)
    wl = _workload(cfg)
    rng = np.random.default_rng(1)
    assert engine.sanitizer_stats() == SanitizerStats()

    req = _mk_req(wl, rng)
    engine.prepare("m", req, rng)
    _finish(engine, req)
    s = engine.sanitizer_stats()
    assert s.runs == engine.runs_executed
    assert 0 < s.host_syncs <= s.runs


def test_default_backend_reports_zero_stats():
    s = Backend().sanitizer_stats()
    assert s == SanitizerStats()
    assert s.ok                          # trivially satisfied


def test_multibackend_aggregates_and_routes():
    cfg = _tiny()
    wl = _workload(cfg)
    rng = np.random.default_rng(2)
    a, b = JaxEngine(cfg, max_len=64), JaxEngine(cfg, max_len=64)
    mux = MultiBackend({"a": a, "b": b})

    req = _mk_req(wl, rng)
    a.prepare("a", req, rng)
    _finish(a, req)

    # routed query hits the named engine; the other is untouched
    assert mux.sanitizer_stats("a") == a.sanitizer_stats()
    assert mux.sanitizer_stats("b") == SanitizerStats()

    agg = mux.sanitizer_stats()
    assert agg.runs == a.sanitizer_stats().runs
    assert agg.retraces == a.sanitizer_stats().retraces
    assert agg.max_syncs_per_run == a.sanitizer_stats().max_syncs_per_run

    # shared instance registered under two names is counted once
    shared = MultiBackend({"x": a, "y": a})
    assert shared.sanitizer_stats().runs == a.sanitizer_stats().runs
