"""Property test (hypothesis): paged-arena slot pool under any interleaving.

Any interleaving of prepare (slot issue, growing the arena on demand),
release (slot free, possibly shrinking/compacting), and explicit shrink
probes must keep the free-slot pool consistent — no slot leaked, none
double-issued, free ∪ used == 0..n_slots-1 exactly — and leave every
request's decode output bit-exact vs the same prompt on a fresh engine.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.serving.engine import JaxEngine
from test_engine_memory import (_finish, _mk_req, _pool_consistent,
                                _prefill, _tiny, _workload)

_CFG = _tiny()
_WL = _workload(_CFG)


@settings(max_examples=8, deadline=None, derandomize=True,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=st.lists(st.integers(0, 3), min_size=1, max_size=10))
def test_slot_pool_consistent_under_any_interleaving(ops):
    engine = JaxEngine(_CFG, max_len=32, n_slots=2, max_slots=32,
                       min_slots=2)
    rng = np.random.default_rng(1234)
    live, done, prompts = [], [], {}
    for op in ops:
        if op in (0, 1) and len(live) < 8:       # prepare + prefill
            r = _mk_req(_WL, rng, 5, 2)
            p = rng.integers(2, _CFG.vocab_size, size=5)
            engine.register(r, p)
            _prefill(engine, r)
            prompts[r.rid] = p
            live.append(r)
        elif op == 2 and live:                   # finish oldest (release)
            r = live.pop(0)
            _finish(engine, r)
            done.append(r)
        elif op == 3:                            # explicit reclamation probe
            engine._maybe_shrink()
        _pool_consistent(engine)
        assert engine.slots_in_use == len(live)
        assert engine.n_slots <= 32
    for r in live:                               # drain the rest
        _finish(engine, r)
        done.append(r)
        _pool_consistent(engine)

    # decode bit-exactness vs a fresh engine, request by request
    ref = JaxEngine(_CFG, max_len=32, n_slots=8)
    rng2 = np.random.default_rng(5678)
    for r in done:
        q = _mk_req(_WL, rng2, 5, 2)
        ref.register(q, prompts[r.rid])
        _finish(ref, q)
        assert engine.states[r.rid].generated == ref.states[q.rid].generated
