"""Real-JAX engine: lazily batched serving must reproduce isolated results.

The strongest system invariant we can test: whatever the scheduler does
(preemption, catch-up, ragged merging), every request's generated tokens
must be IDENTICAL to generating the same prompt alone. Exercised across
three architecture families (dense GQA, MLA, SSM).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policies import CellularBatching, LazyBatching
from repro.core.request import SubBatch
from repro.core.slack import SlackPredictor
from repro.serving.engine import JaxEngine
from repro.serving.npu_model import NPUPerfModel, TPU_V5E
from repro.serving.server import InferenceServer
from repro.serving.traffic import Trace
from repro.serving.workload import LengthDist, from_model_config


def _tiny(arch):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                               num_prefix_embeddings=0)


def _serve(arch, n=5, seed=0, policy="lazyb"):
    cfg = _tiny(arch)
    rng = np.random.default_rng(seed)
    wl = from_model_config(cfg,
                           prompt_dist=LengthDist((5, 7, 9), (1/3,) * 3),
                           decode_dist=LengthDist((2, 3), (0.5, 0.5)))
    engine = JaxEngine(cfg, max_len=32)
    reqs, prompts = [], {}
    t = 0.0
    for _ in range(n):
        t += rng.exponential(0.05)
        r = wl.sample_request(rng, t)
        prompt = rng.integers(2, cfg.vocab_size, size=r.prompt_len)
        prompts[r.rid] = prompt
        engine.register(r, prompt)
        reqs.append(r)
    if policy == "lazyb":
        pred = SlackPredictor.build([wl], NPUPerfModel(TPU_V5E), 60.0)
        pol = LazyBatching(pred, max_batch=4)
    else:
        pol = CellularBatching(max_batch=4)
    stats = InferenceServer(pol, engine).run(Trace(reqs, t))
    assert len(stats.finished) == n
    return cfg, wl, engine, reqs, prompts


def _reference(cfg, wl, prompt, n_tokens):
    engine = JaxEngine(cfg, max_len=32)
    rng = np.random.default_rng(0)
    req = wl.sample_request(rng, 0.0)
    seq, prefix_len, cycle_len = wl.build_sequence(len(prompt), n_tokens)
    req.sequence, req.prefix_len, req.cycle_len = seq, prefix_len, cycle_len
    req.prompt_len, req.decode_len = len(prompt), n_tokens
    engine.register(req, prompt)
    sb = SubBatch([req])
    while not req.done:
        engine.execute("m", sb, req.next_node_id)
        sb.advance(0.0)
    return engine.states[req.rid].generated[:n_tokens]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b", "mamba2-2.7b"])
def test_lazy_batching_preserves_generations(arch):
    cfg, wl, engine, reqs, prompts = _serve(arch, n=4)
    for r in reqs:
        got = engine.states[r.rid].generated[:r.decode_len]
        ref = _reference(cfg, wl, prompts[r.rid], r.decode_len)
        assert got == ref, f"{arch} rid={r.rid}: {got} != {ref}"


def test_cellular_also_preserves_generations():
    cfg, wl, engine, reqs, prompts = _serve("llama3.2-1b", n=3,
                                            policy="cellular")
    for r in reqs:
        got = engine.states[r.rid].generated[:r.decode_len]
        ref = _reference(cfg, wl, prompts[r.rid], r.decode_len)
        assert got == ref
