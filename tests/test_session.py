"""Online serving API tests: session/handle lifecycle, per-request SLA
classes, streaming, memo eviction, and the offline-compat wrapper.

JAX-engine cases run on a tiny reduced config (CPU-runnable); everything
else drives the analytic simulator through the same Backend contract.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LazyBatching, Oracle, OracleSlackPredictor, Serial,
                        SLAClass, SlackPredictor)
from repro.core.request import Request
from repro.serving import (HandleState, NPUPerfModel, PAPER_NPU, ServeStats,
                           ServingSession, SimExecutor, TPU_V5E, Trace,
                           get_workload, poisson_trace, run_trace,
                           with_sla_classes)
from repro.serving.server import InferenceServer

PERF = NPUPerfModel(PAPER_NPU)
MS = 1e-3


def lazyb(wl, sla=0.1, max_batch=16, **kw):
    return LazyBatching(SlackPredictor.build([wl], PERF, sla, **kw),
                        max_batch=max_batch)


# ---------------------------------------------------------------------------
# Handle lifecycle
# ---------------------------------------------------------------------------

LIFECYCLE = [HandleState.QUEUED, HandleState.ADMITTED, HandleState.RUNNING,
             HandleState.DONE]


def test_handle_lifecycle_to_done():
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl), SimExecutor(PERF))
    rng = np.random.default_rng(0)
    h = session.submit(wl.sample_request(rng, arrival=5 * MS))
    # submitted ahead of its arrival: still queued, not yet in the policy
    assert h.state is HandleState.QUEUED
    session.run_until(4 * MS)
    assert h.state is HandleState.QUEUED
    seen = [h.state]
    while not h.done:
        assert session.step()
        if h.state is not seen[-1]:
            seen.append(h.state)
    # monotone walk down the lifecycle (ADMITTED->RUNNING may collapse into
    # one step when admission and the first run share a scheduling step)
    assert [s for s in LIFECYCLE if s in seen] == seen
    assert seen[0] is HandleState.QUEUED and seen[-1] is HandleState.DONE
    assert HandleState.RUNNING in seen
    assert h.t_finish is not None and h.latency > 0
    assert h.ttft is not None and h.ttft <= h.latency
    # an idle-and-empty session reports no work left
    assert not session.step()


def test_admitted_state_observable_between_steps():
    """A request admitted into the batch table whose sub-batch is NOT the
    active (executing) entry reports ADMITTED: co-located workloads are
    admitted as separate stack entries in one step, only the top runs."""
    wl_a, wl_b = get_workload("transformer"), get_workload("resnet")
    pred = SlackPredictor.build([wl_a, wl_b], PERF, 0.5)
    session = ServingSession(LazyBatching(pred, max_batch=8),
                             SimExecutor(PERF))
    rng = np.random.default_rng(20)
    ha = session.submit(wl_a.sample_request(rng, 0.0))
    hb = session.submit(wl_b.sample_request(rng, 0.0))
    assert session.step()               # admits both, runs the top entry
    states = {ha.state, hb.state}
    assert HandleState.ADMITTED in states
    assert HandleState.RUNNING in states
    session.drain()
    assert ha.state is hb.state is HandleState.DONE


def test_handle_rejected_on_admission_refusal():
    """A request whose own deadline is unmeetable even running alone is
    REJECTED at submit when admission control is on."""
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl), SimExecutor(PERF),
                             reject_infeasible=True)
    rng = np.random.default_rng(1)
    doomed = wl.sample_request(rng, 0.0)
    doomed.sla = SLAClass("impossible", 1e-9)
    ok = wl.sample_request(rng, 0.0)
    h_bad = session.submit(doomed)
    h_ok = session.submit(ok)
    assert h_bad.state is HandleState.REJECTED
    assert h_bad.done
    assert h_ok.state is HandleState.QUEUED
    stats = session.drain()
    assert h_ok.state is HandleState.DONE
    assert stats.rejected == 1
    assert len(stats.finished) == 1
    # rejected requests never touch the policy queue or the batch table
    assert session.policy.outstanding == 0


def test_rejection_releases_predictor_memo():
    """The feasibility probe memoizes predictor state for requests the
    policy never sees finish — rejection must release it (regression)."""
    wl = get_workload("transformer")
    pol = Oracle(OracleSlackPredictor(0.1, PERF), max_batch=8)
    session = ServingSession(pol, SimExecutor(PERF), reject_infeasible=True)
    rng = np.random.default_rng(13)
    for _ in range(5):
        r = wl.sample_request(rng, 0.0)
        r.sla = SLAClass("impossible", 1e-9)
        assert session.submit(r).state is HandleState.REJECTED
    assert pol.predictor.memo_size == 0


def test_release_drops_finished_handle_state():
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl), SimExecutor(PERF))
    rng = np.random.default_rng(14)
    h1 = session.submit(wl.sample_request(rng, 0.0))
    h2 = session.submit(wl.sample_request(rng, 1 * MS))
    with pytest.raises(ValueError, match="live request"):
        session.release(h1)                 # still live: refused (a real
        #                                     error even under -O, so a
        #                                     mid-flight release can never
        #                                     silently drop request state)
    session.drain()
    session.release(h1)
    assert h1.request.rid not in session.handles
    assert len(session.stats().finished) == 1
    assert session.stats().finished[0].rid == h2.request.rid


def test_submit_mid_flight_and_run_until():
    """Online use: submissions interleave with clock advancement."""
    wl = get_workload("transformer")
    session = ServingSession(lazyb(wl), SimExecutor(PERF))
    rng = np.random.default_rng(2)
    h1 = session.submit(wl.sample_request(rng, 0.0))
    session.run_until(2 * MS)
    assert session.now >= 2 * MS
    # a stale arrival submitted mid-flight is clamped to the session clock:
    # waiting time / latency count from the submission instant
    late = wl.sample_request(rng, 0.0)
    t_submit = session.now
    h2 = session.submit(late)
    assert late.arrival == t_submit
    session.drain()
    assert h1.state is h2.state is HandleState.DONE
    assert h2.t_finish >= 2 * MS
    assert h2.latency <= h2.t_finish - t_submit + 1e-12


# ---------------------------------------------------------------------------
# Per-request SLA classes
# ---------------------------------------------------------------------------

def test_slack_uses_per_request_deadline():
    wl = get_workload("transformer")
    pred = SlackPredictor.build([wl], PERF, sla_target=100 * MS)
    rng = np.random.default_rng(3)
    req = wl.sample_request(rng, 0.0)
    base = pred.slack(req, [req], now=0.0)
    req.sla = SLAClass("gold", 40 * MS)
    tight = pred.slack(req, [req], now=0.0)
    assert tight == pytest.approx(base - 60 * MS)
    # oracle predictor honors it too
    orc = OracleSlackPredictor(100 * MS, PERF)
    assert (orc.slack(req, [req], 0.0)
            < orc.slack(dataclasses_replace_sla(req, None), [req], 0.0))


def dataclasses_replace_sla(req, sla):
    clone = req.clone()
    clone.sla = sla
    return clone


def test_authorize_honors_tightest_member():
    """A merge fine for the global target must be refused when one member
    carries a tighter class deadline."""
    wl = get_workload("transformer")
    pred = SlackPredictor.build([wl], PERF, sla_target=1.0)
    rng = np.random.default_rng(4)
    ongoing = [wl.sample_request(rng, 0.0) for _ in range(2)]
    pending = [wl.sample_request(rng, 0.0) for _ in range(6)]
    assert pred.authorize(ongoing, pending, now=0.0)
    single = pred.single_remaining(ongoing[0])
    # deadline below the merged-batch conservative bound -> refuse
    ongoing[0].sla = SLAClass("gold", deadline=4 * single)
    assert not pred.authorize(ongoing, pending, now=0.0)
    # ... and fine again once the pending prefix shrinks enough
    assert pred.authorize(ongoing, [], now=0.0)


def test_mixed_tiers_tight_class_gets_better_p99():
    """Under lazyb at overload, the tight-deadline tier must get strictly
    better p99 than the loose tier (EDF admission + per-deadline
    authorization), with per-class attainment reported."""
    wl = get_workload("transformer")
    gold, bulk = SLAClass("gold", 30 * MS), SLAClass("bulk", 500 * MS)
    trace = poisson_trace(wl, rate=1200, duration=0.25, seed=0)
    with_sla_classes(trace, [gold, bulk], seed=0)
    stats = run_trace(lazyb(wl), SimExecutor(PERF), trace.fresh())
    assert len(stats.finished) == len(trace.requests)
    pc = stats.per_class()
    assert set(pc) == {"gold", "bulk"}
    assert pc["gold"]["completed"] + pc["bulk"]["completed"] == len(trace.requests)
    # strictly better tail latency for the tight tier — by a wide margin
    assert pc["gold"]["p99_ms"] < 0.7 * pc["bulk"]["p99_ms"]
    # per-class attainment is judged against each class's own deadline
    assert pc["gold"]["sla_attainment"] >= 0.95
    assert pc["bulk"]["sla_attainment"] >= 0.95
    s = stats.summary(sla=0.1)
    assert "sla_viol[gold]" in s and "sla_viol[bulk]" in s


def test_single_class_trace_identical_to_untiered():
    """Attaching ONE class whose deadline equals the global target must not
    change scheduling at all (EDF == FIFO, authorize unchanged)."""
    wl = get_workload("transformer")
    trace = poisson_trace(wl, rate=900, duration=0.1, seed=5)
    base = run_trace(lazyb(wl, sla=0.1), SimExecutor(PERF), trace.fresh())
    tiered = trace.fresh()
    for r in tiered.requests:
        r.sla = SLAClass("only", 0.1)
    tst = run_trace(lazyb(wl, sla=0.1), SimExecutor(PERF), tiered)
    lat_a = sorted((r.rid, r.latency()) for r in base.finished)
    lat_b = sorted((r.rid, r.latency()) for r in tst.finished)
    assert lat_a == lat_b


# ---------------------------------------------------------------------------
# Predictor memo eviction (regression: unbounded (rid, idx) growth)
# ---------------------------------------------------------------------------

def test_slack_memo_evicted_on_completion():
    wl = get_workload("transformer")
    pol = lazyb(wl, sla=0.1, max_batch=16)
    trace = poisson_trace(wl, rate=800, duration=0.5, seed=6)
    stats = run_trace(pol, SimExecutor(PERF), trace.fresh())
    assert len(stats.finished) == len(trace.requests) > 300
    # every finished request's entries were dropped: nothing left
    assert pol.predictor.memo_size == 0
    assert pol.predictor._memo == {}


def test_oracle_memo_evicted_on_completion():
    wl = get_workload("transformer")
    pol = Oracle(OracleSlackPredictor(0.1, PERF), max_batch=16)
    trace = poisson_trace(wl, rate=300, duration=0.2, seed=7)
    stats = run_trace(pol, SimExecutor(PERF), trace.fresh())
    assert len(stats.finished) == len(trace.requests)
    assert pol.predictor.memo_size == 0


def test_slack_memo_bounded_during_serving():
    """Mid-flight, the memo only holds entries for live requests."""
    wl = get_workload("transformer")
    pol = lazyb(wl, sla=0.1, max_batch=16)
    session = ServingSession(pol, SimExecutor(PERF))
    rng = np.random.default_rng(8)
    t = 0.0
    for _ in range(200):
        t += rng.exponential(1 / 600)
        session.submit(wl.sample_request(rng, t))
    session.run_until(t / 2)
    live = {h.request.rid for h in session.handles.values() if not h.done}
    assert set(pol.predictor._memo) <= live
    session.drain()
    assert pol.predictor.memo_size == 0


# ---------------------------------------------------------------------------
# Streaming on the real JAX engine: bit-exact vs batch execution
# ---------------------------------------------------------------------------

def _tiny(arch):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=128,
                               num_prefix_embeddings=0)


def test_jax_streamed_tokens_bit_exact():
    from repro.serving.engine import JaxEngine
    from repro.serving.workload import LengthDist, from_model_config

    cfg = _tiny("llama3.2-1b")
    wl = from_model_config(cfg,
                           prompt_dist=LengthDist((5, 7, 9), (1 / 3,) * 3),
                           decode_dist=LengthDist((2, 3), (0.5, 0.5)))
    engine = JaxEngine(cfg, max_len=32)
    pred = SlackPredictor.build([wl], NPUPerfModel(TPU_V5E), 60.0)
    session = ServingSession(LazyBatching(pred, max_batch=4), engine, seed=0)
    rng = np.random.default_rng(0)
    streamed = {}

    def on_token(handle, token):
        streamed.setdefault(handle.request.rid, []).append(token)

    handles = []
    t = 0.0
    for _ in range(5):
        t += rng.exponential(0.05)
        r = wl.sample_request(rng, t)
        prompt = rng.integers(2, cfg.vocab_size, size=r.prompt_len)
        handles.append(session.submit(r, prompt_tokens=prompt,
                                      on_token=on_token))
    stats = session.drain()
    assert len(stats.finished) == 5
    for h in handles:
        r = h.request
        assert h.state is HandleState.DONE
        batch = engine.states[r.rid].generated[:r.decode_len]
        assert len(batch) == r.decode_len > 0
        # streamed callbacks and handle.tokens both equal the batch result
        assert streamed[r.rid][:r.decode_len] == batch
        assert h.tokens[:r.decode_len] == batch
        # TTFT stamped at the run boundary that emitted token #1
        assert r.t_first_token is not None
        assert r.arrival <= r.t_first_token <= r.t_finish
    # releasing handles drops the engine's per-request state too (the
    # long-lived-session leak path); results were captured above
    assert engine.slots_in_use == 0
    for h in handles:
        session.release(h)
    assert engine.states == {}
    assert session.stats().finished == []


def test_jax_mixed_tier_trace_reports_per_class():
    """Acceptance: a mixed two-tier trace through ServingSession on the
    REAL engine completes with per-class SLA attainment reported."""
    from repro.serving.engine import JaxEngine
    from repro.serving.workload import LengthDist, from_model_config

    cfg = _tiny("llama3.2-1b")
    wl = from_model_config(cfg,
                           prompt_dist=LengthDist((5, 7), (0.5, 0.5)),
                           decode_dist=LengthDist((2, 3), (0.5, 0.5)))
    engine = JaxEngine(cfg, max_len=32)
    pred = SlackPredictor.build([wl], NPUPerfModel(TPU_V5E), 60.0)
    session = ServingSession(LazyBatching(pred, max_batch=4), engine, seed=0)
    rng = np.random.default_rng(1)
    tiers = [SLAClass("gold", 30.0), SLAClass("bulk", 600.0)]
    t = 0.0
    for i in range(4):
        t += rng.exponential(0.05)
        r = wl.sample_request(rng, t)
        r.sla = tiers[i % 2]
        session.submit(r)                # engine samples the prompt itself
    stats = session.drain()
    assert len(stats.finished) == 4
    pc = stats.per_class()
    assert set(pc) == {"gold", "bulk"}
    for name in ("gold", "bulk"):
        assert pc[name]["completed"] == 2
        assert not math.isnan(pc[name]["sla_attainment"])
        assert not math.isnan(pc[name]["ttft_ms"])


# ---------------------------------------------------------------------------
# Metrics: p50 + per-class NaN safety
# ---------------------------------------------------------------------------

def test_summary_p50_and_nan_safe_empty_class():
    wl = get_workload("transformer")
    trace = poisson_trace(wl, rate=300, duration=0.1, seed=9)
    stats = run_trace(lazyb(wl), SimExecutor(PERF), trace.fresh())
    s = stats.summary(sla=0.1)
    assert s["p25_ms"] <= s["p50_ms"] <= s["p75_ms"] <= s["p99_ms"]
    # a declared class with no finishers reports NaN, not a crash
    stats.classes["ghost"] = 0.05
    s2 = stats.summary(sla=0.1)
    assert math.isnan(s2["sla_viol[ghost]"])
    pc = stats.per_class(sla=0.1)
    assert math.isnan(pc["ghost"]["p99_ms"])
    assert math.isnan(pc["ghost"]["sla_attainment"])
    assert pc["ghost"]["completed"] == 0
    # empty stats entirely NaN-safe
    empty = ServeStats(policy="x", duration=1.0)
    assert math.isnan(empty.summary(sla=0.1)["p50_ms"])
    assert math.isnan(empty.ttft())
    assert math.isnan(empty.tpot())


def test_ttft_tpot_reported_for_cyclic_workloads():
    wl = get_workload("transformer")
    trace = poisson_trace(wl, rate=200, duration=0.1, seed=10)
    stats = run_trace(lazyb(wl), SimExecutor(PERF), trace.fresh())
    assert stats.ttft() > 0
    assert stats.tpot() > 0
    # TTFT <= full latency for every request
    for r in stats.finished:
        assert r.t_first_token is not None
        assert r.arrival < r.t_first_token <= r.t_finish


# ---------------------------------------------------------------------------
# Offline-compat wrapper
# ---------------------------------------------------------------------------

def test_run_trace_matches_inference_server():
    wl = get_workload("transformer")
    trace = poisson_trace(wl, rate=600, duration=0.1, seed=11)
    a = run_trace(lazyb(wl), SimExecutor(PERF), trace.fresh())
    srv = InferenceServer(lazyb(wl), SimExecutor(PERF))
    b = srv.run(trace.fresh())
    assert sorted((r.rid, r.latency()) for r in a.finished) == \
        sorted((r.rid, r.latency()) for r in b.finished)
    assert srv.log.nodes_executed > 0       # wrapper still fills the log


def test_serial_policy_through_session():
    """Policies without a predictor run through the session unchanged."""
    wl = get_workload("resnet")
    trace = poisson_trace(wl, rate=100, duration=0.05, seed=12)
    stats = run_trace(Serial(), SimExecutor(PERF), trace.fresh())
    assert len(stats.finished) == len(trace.requests)
    # static graph: exactly one (virtual) token, TTFT == finish time
    for r in stats.finished:
        assert r.n_tokens == 1
        assert r.t_first_token == r.t_finish
