"""Data pipeline: determinism, shapes, next-token alignment, length stats."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataConfig, TokenPipeline, make_batch_specs
from repro.configs import get_config, get_shape
from repro.serving.workload import wmt_like_length_dist


def test_shapes_and_alignment():
    cfg = DataConfig(vocab_size=1000, seq_len=128, batch_size=4, seed=1)
    b = TokenPipeline(cfg).next_batch()
    assert b["tokens"].shape == (4, 128)
    assert b["targets"].shape == (4, 128)
    # targets are tokens shifted by one (same underlying stream)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    assert b["tokens"].max() < 1000 and b["tokens"].min() >= 0


def test_determinism_by_seed():
    mk = lambda s: TokenPipeline(DataConfig(500, 64, 2, seed=s)).next_batch()
    a, b, c = mk(7), mk(7), mk(8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(16, 100))
def test_stream_properties(batch, seq):
    cfg = DataConfig(vocab_size=300, seq_len=seq, batch_size=batch, seed=3)
    b = TokenPipeline(cfg).next_batch()
    assert b["tokens"].shape == (batch, seq)
    assert (b["tokens"] != cfg.pad_id).any()


def test_wmt_length_dist_anchors():
    """Fig. 11 anchors: ~70% of sentences <= 20 words, ~90% <= 30."""
    d = wmt_like_length_dist(80)
    probs = np.asarray(d.probs)
    le20 = probs[:20].sum()
    le30 = probs[:30].sum()
    assert 0.60 < le20 < 0.85, le20
    assert 0.85 < le30 < 0.95, le30
    assert d.quantile(0.9) <= 35


def test_batch_specs_cover_all_shapes():
    cfg = get_config("internvl2-26b")
    for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        shape = get_shape(shape_name)
        specs = make_batch_specs(cfg, shape)
        if shape.kind == "train":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
            assert "prefix" in specs          # VLM stub embeddings
        elif shape.kind == "prefill":
            assert "tokens" in specs
        else:
            assert specs["token"].shape == (shape.global_batch,)
            assert specs["pos"].shape == (shape.global_batch,)
