"""Launcher: param-spec derivation, HLO collective parsing, mini dry-run."""
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_stats
from repro.launch.mesh import param_pspec


class _K:
    def __init__(self, key):
        self.key = key


def _spec(path_keys, shape, model_n=16, data_n=16, fsdp=True):
    return param_pspec([_K(k) for k in path_keys], shape,
                       model_n=model_n, data_n=data_n, fsdp=fsdp, pod=False)


def test_param_specs_name_table():
    # embed: vocab over model, d over data
    assert _spec(["embed", "tok"], (152064, 5120)) == P("model", "data")
    # attention q: heads preferred but 40 % 16 != 0 -> fallback dim
    s = _spec(["blocks", "attn", "wq"], (64, 5120, 40, 128))
    assert s[0] is None                      # stacked layer dim never sharded
    assert "model" in s
    # mlp: ff over model
    assert _spec(["blocks", "mlp", "w_gate"], (16, 2048, 8192))[2] == "model"
    assert _spec(["blocks", "mlp", "w_down"], (16, 8192, 2048))[1] == "model"


def test_param_specs_scalars_and_small():
    assert _spec(["opt", "step"], ()) == P()
    assert _spec(["blocks", "ssm", "A_log"], (64, 80)) == P(None, "model")
    # nothing divisible -> fully replicated
    assert _spec(["blocks", "x"], (64, 7, 9)) == P(None, None, None)


def test_no_fsdp_when_disabled():
    s = _spec(["blocks", "mlp", "w_gate"], (16, 2048, 8192), fsdp=False)
    assert "data" not in tuple(s)


SAMPLE_HLO = textwrap.dedent("""\
    HloModule test
    %add { }
    ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
      %p0 = f32[16,128]{1,0} parameter(0)
      %ag = f32[256,128]{1,0} all-gather(f32[16,128]{1,0} %p0), dimensions={0}
      %c = bf16[256,128]{1,0} convert(%ag)
      %ar = bf16[256,128]{1,0} all-reduce(bf16[256,128]{1,0} %c), to_apply=%add
      %rs = bf16[16,128]{1,0} reduce-scatter(%ar), dimensions={0}
      ROOT %out = f32[16,128]{1,0} convert(%rs)
    }
""")


def test_collective_stats_parsing():
    stats = hlo_stats.collective_stats(SAMPLE_HLO)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 16 * 128 * 4      # operand bytes
    assert stats["all-reduce"]["bytes"] == 256 * 128 * 2     # bf16 operand
    # reduce-scatter operand resolved via the symbol table (%ar)
    assert stats["reduce-scatter"]["bytes"] == 256 * 128 * 2
    assert hlo_stats.total_collective_bytes(SAMPLE_HLO) == (
        16 * 128 * 4 + 256 * 128 * 2 + 256 * 128 * 2)


_DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses, jax
from repro.launch.steps import build_combo
from repro.sharding import make_rules, use_rules

mesh = jax.make_mesh((4, 4), ("data", "model"))
combo = build_combo("llama3.2-1b", "decode_32k", mesh,
                    cfg_overrides=dict(num_layers=2, d_model=256, d_ff=512,
                                       num_heads=4, num_kv_heads=4,
                                       head_dim=64, vocab_size=512))
rules = make_rules(mesh, "serve")
with mesh, use_rules(rules):
    lowered = jax.jit(combo.fn, in_shardings=combo.in_shardings).lower(*combo.args)
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
cost = compiled.cost_analysis()
assert cost.get("flops", 0) > 0
print("MINI-DRYRUN-OK")
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """End-to-end lower+compile of a reduced arch on a 16-device host mesh
    (subprocess: the 512-device flag must not leak into this test session)."""
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "MINI-DRYRUN-OK" in r.stdout, r.stderr[-2000:]
