"""§Perf optimization variants must be numerically equivalent to the
paper-faithful baselines (optimizations change HLO, not math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.model import Model, RuntimeFlags


def test_grouped_decode_matches_baseline():
    cfg = get_config("llama3.2-1b").reduced()
    key = jax.random.key(0)
    p = L.init_attention(key, cfg, jnp.float32)
    B, T = 3, 64
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, cfg.d_model), jnp.float32)
    cache = {
        "k": jax.random.normal(ks[1], (B, T, cfg.num_kv_heads, cfg.head_dim)),
        "v": jax.random.normal(ks[2], (B, T, cfg.num_kv_heads, cfg.head_dim)),
    }
    pos = jnp.array([5, 20, 63], jnp.int32)
    y0, c0 = L.apply_attention_decode(p, x, cache, pos, cfg, grouped=False)
    y1, c1 = L.apply_attention_decode(p, x, cache, pos, cfg, grouped=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c0["k"]), np.asarray(c1["k"]))


@pytest.mark.parametrize("window", [None, 24])
def test_mla_absorbed_matches_baseline(window):
    cfg = get_config("minicpm3-4b").reduced()
    key = jax.random.key(1)
    p = L.init_mla(key, cfg, jnp.float32)
    B, S = 2, 64
    x = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model), jnp.float32)
    y0, cache0 = L.apply_mla_dense(p, x, cfg, chunk=32, window=window,
                                   absorbed=False)
    y1, cache1 = L.apply_mla_dense(p, x, cfg, chunk=32, window=window,
                                   absorbed=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache0["ckv"]),
                               np.asarray(cache1["ckv"]), rtol=1e-6)


def test_mla_absorbed_prefill_consistent_with_decode():
    """Prefill with absorbed attention then one decode step == prefill of
    the extended sequence (same final logits)."""
    cfg = get_config("minicpm3-4b").reduced()
    model_a = Model(cfg, RuntimeFlags(dtype=jnp.float32, attn_chunk=16,
                                      mla_absorbed=True))
    model_b = Model(cfg, RuntimeFlags(dtype=jnp.float32, attn_chunk=16))
    params = model_a.init(jax.random.key(3))
    toks = jax.random.randint(jax.random.key(4), (1, 17), 0, cfg.vocab_size)
    la, _ = model_a.prefill(params, toks)
    lb, _ = model_b.prefill(params, toks)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-3, atol=2e-3)


def test_grouped_decode_full_model_equivalence():
    cfg = get_config("qwen2.5-32b").reduced()
    m0 = Model(cfg, RuntimeFlags(dtype=jnp.float32, attn_chunk=16))
    m1 = Model(cfg, RuntimeFlags(dtype=jnp.float32, attn_chunk=16,
                                 grouped_decode=True))
    params = m0.init(jax.random.key(5))
    toks = jax.random.randint(jax.random.key(6), (2, 9), 0, cfg.vocab_size)
    _, cache = m0.prefill(params, toks, max_len=32)

    def pad(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == 9:     # (L, B, T, ...)
            return jnp.pad(leaf, [(0, 0), (0, 0), (0, 32 - 9)]
                           + [(0, 0)] * (leaf.ndim - 3))
        return leaf

    cache = jax.tree.map(pad, cache)
    tok = jnp.array([3, 4], jnp.int32)
    pos = jnp.array([9, 9], jnp.int32)
    l0, _ = m0.decode_step(params, cache, tok, pos)
    l1, _ = m1.decode_step(params, cache, tok, pos)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_close_to_exact():
    cfg = get_config("llama3.2-1b").reduced()
    key = jax.random.key(7)
    p = L.init_attention(key, cfg, jnp.float32)
    B, T = 2, 64
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, cfg.d_model), jnp.float32)
    pos = jnp.array([10, 40], jnp.int32)
    # build matching exact + quantized caches from the same history
    hist = jax.random.normal(ks[1], (B, T, cfg.num_kv_heads, cfg.head_dim))
    valid = jnp.arange(T)[None, :, None, None] < pos[:, None, None, None]
    hist = jnp.where(valid, hist, 0.0)
    exact = {"k": hist, "v": hist * 0.7}
    kq, ksc = L._quantize_rows(hist)
    vq, vsc = L._quantize_rows(hist * 0.7)
    quant = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    y0, c0 = L.apply_attention_decode(p, x, exact, pos, cfg)
    y1, c1 = L.apply_attention_decode(p, x, quant, pos, cfg)
    assert c1["k"].dtype == jnp.int8
    # int8 cache: outputs agree to quantization tolerance
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=0.1, atol=0.05)


def test_int8_kv_cache_full_model_decodes():
    cfg = get_config("llama3.2-1b").reduced()
    m = Model(cfg, RuntimeFlags(dtype=jnp.float32, kv_quant=True))
    params = m.init(jax.random.key(8))
    cache = m.init_cache(2, 32)
    tok = jnp.array([3, 4], jnp.int32)
    pos = jnp.array([0, 5], jnp.int32)
    logits, new_cache = m.decode_step(params, cache, tok, pos)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_pallas_decode_path_matches_baseline():
    """The integrated ragged-attention kernel path (RuntimeFlags.
    pallas_decode) equals the jnp decode across a merged ragged batch."""
    cfg = get_config("llama3.2-1b").reduced()
    key = jax.random.key(9)
    p = L.init_attention(key, cfg, jnp.float32)
    B, T = 3, 64
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (B, cfg.d_model), jnp.float32)
    cache = {
        "k": jax.random.normal(ks[1], (B, T, cfg.num_kv_heads, cfg.head_dim)),
        "v": jax.random.normal(ks[2], (B, T, cfg.num_kv_heads, cfg.head_dim)),
    }
    pos = jnp.array([0, 17, 63], jnp.int32)          # ragged progress
    y0, _ = L.apply_attention_decode(p, x, cache, pos, cfg)
    y1, _ = L.apply_attention_decode(p, x, cache, pos, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
