"""Slack predictor unit tests (paper Eq. 1-2, Algorithm 1)."""
import numpy as np
import pytest

from repro.core.request import Request
from repro.core.slack import SlackPredictor, OracleSlackPredictor
from repro.serving.npu_model import NPUPerfModel, PAPER_NPU
from repro.serving.workload import (Workload, NodeDesc, Segment, LengthDist,
                                    get_workload)

PERF = NPUPerfModel(PAPER_NPU)
MS = 1e-3


def toy_workload(n_nodes=8):
    """Static graph whose nodes cost ~1ms each (weight-traffic bound)."""
    wb = 360e9 * (1e-3 - PAPER_NPU.node_overhead)
    nodes = {f"n{i}": NodeDesc(f"n{i}", flops=0.0, weight_bytes=wb,
                               act_bytes=0.0) for i in range(n_nodes)}
    return Workload("toy", nodes, [Segment(tuple(nodes))], kind="static")


def mk_static_req(wl, arrival=0.0):
    seq, pl, cl = wl.build_sequence(0, 0)
    return Request(workload=wl, arrival=arrival, sequence=seq,
                   prefix_len=pl, cycle_len=cl)


def test_eq1_slack_without_batching():
    """Paper running example: SLA=30u, T_wait=2u, exec=8u -> slack=20u."""
    wl = toy_workload(8)
    pred = SlackPredictor.build([wl], PERF, sla_target=30 * MS)
    req = mk_static_req(wl)
    slack = pred.slack(req, [req], now=2 * MS)
    assert slack == pytest.approx(20 * MS, rel=0.01)


def test_eq2_batched_slack_is_sum_of_singles():
    """Eq. 2: batching with N-1 others subtracts each one's single time."""
    wl = toy_workload(8)
    pred = SlackPredictor.build([wl], PERF, sla_target=30 * MS)
    reqs = [mk_static_req(wl) for _ in range(3)]
    slack1 = pred.slack(reqs[0], reqs[:1], now=0.0)
    slack3 = pred.slack(reqs[0], reqs, now=0.0)
    single = pred.single_remaining(reqs[0])
    assert slack1 - slack3 == pytest.approx(2 * single, rel=1e-6)


def test_slack_shrinks_with_wait_time():
    wl = toy_workload(4)
    pred = SlackPredictor.build([wl], PERF, sla_target=30 * MS)
    req = mk_static_req(wl)
    s0 = pred.slack(req, [req], now=0.0)
    s5 = pred.slack(req, [req], now=5 * MS)
    assert s5 == pytest.approx(s0 - 5 * MS, rel=1e-9)


def test_conservative_vs_oracle_ordering():
    """Conservative slack (sum of singles) <= oracle slack (batched curve)."""
    wl = get_workload("gnmt")
    pred = SlackPredictor.build([wl], PERF, sla_target=100 * MS)
    oracle = OracleSlackPredictor(100 * MS, PERF)
    rng = np.random.default_rng(0)
    reqs = [wl.sample_request(rng, 0.0) for _ in range(4)]
    s_cons = pred.slack(reqs[0], reqs, now=0.0)
    s_orac = oracle.slack(reqs[0], reqs, now=0.0)
    assert s_cons <= s_orac + 1e-9


def test_dec_timesteps_overprovision():
    """Predicted remaining decode length uses the N%-quantile, never the
    request's true (hidden) output length (Algorithm 1 lines 8-9)."""
    wl = get_workload("gnmt")
    pred = SlackPredictor.build([wl], PERF, sla_target=1.0, coverage=0.90)
    dec_ts = pred.dec_timesteps["gnmt"]
    assert dec_ts == wl.decode_dist.quantile(0.90)
    rng = np.random.default_rng(1)
    # find a short-output request: prediction must exceed its true remaining
    for _ in range(50):
        req = wl.sample_request(rng, 0.0)
        if req.decode_len <= dec_ts // 2:
            break
    assert req.decode_len <= dec_ts // 2
    predicted = pred.single_remaining(req)
    true_nodes = req.sequence[req.idx:]
    table = pred.tables["gnmt"]
    true_rem = sum(table[nid] for nid, _ in true_nodes)
    assert predicted > true_rem     # conservative overprovision


def test_authorize_monotone_in_pending():
    """Adding pending requests can only flip authorize True -> False."""
    wl = toy_workload(8)
    pred = SlackPredictor.build([wl], PERF, sla_target=10 * MS)
    ongoing = [mk_static_req(wl)]
    pend = [mk_static_req(wl) for _ in range(8)]
    results = [pred.authorize(ongoing, pend[:k], now=0.0)
               for k in range(len(pend) + 1)]
    # once False, stays False
    seen_false = False
    for r in results:
        if seen_false:
            assert not r
        seen_false = seen_false or (not r)
    assert results[0] is True        # no pending: trivially fine
    assert results[-1] is False      # 9 x ~8ms >> 10ms SLA
